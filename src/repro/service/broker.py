"""OffloadBroker — async multi-tenant partition service (serving tier).

The paper's adaptive loop (Fig. 1) is per-user: profile once, monitor
the environment, re-partition on drift.  At serving scale millions of
users run the *same* profiled applications through a handful of
recurring environment regimes, so solving each repartition point
one-at-a-time wastes both dispatches and solutions.  The broker is the
subsystem that turns the PR-2 throughput primitives
(:func:`repro.core.mcop.mcop_batch`,
:class:`repro.core.placement_cache.PlacementCache`) into a long-lived
service:

* **Tenants** — one registered (profile, cost model) pair per served
  application, each with its own shared
  :class:`~repro.core.placement_cache.PlacementCache` guarded by a
  :func:`~repro.core.placement_cache.profile_fingerprint`.
* **Async submit** — per-user controllers
  (:class:`repro.service.session.BrokerSession` wrapping
  :class:`~repro.core.adaptive.AdaptiveController`) and elastic events
  (:meth:`repro.runtime.elastic.ElasticMeshManager.submit_resize`)
  enqueue solve requests and get a :class:`PlacementFuture` back.
* **Coalescing tick** — :meth:`OffloadBroker.tick` drains the queue,
  serves cache hits immediately, coalesces remaining requests by
  (tenant, quantized-environment-bin) down to one representative solve
  per bin, and flushes all representatives through **one**
  ``mcop_batch`` call per static shape bucket.  Followers and hits are
  repriced under their *exact* request graph (same honesty contract as
  the controller), so a tick costs O(distinct bins), not O(requests).
* **Array-native flush** — :meth:`submit` no longer builds a WCG per
  request: construction is deferred to the tick, where each tenant's
  pending environments are built in ONE vectorized
  ``cost_model.build_batch`` call (rows bit-identical to the scalar
  builder), and each bucket's representatives are packed into a
  :class:`~repro.core.graph.WCGBatch` that ``mcop_batch`` dispatches
  directly — no per-request Python graph objects on the hot path.
* **Priority lanes** — elastic resize events
  (:meth:`~repro.runtime.elastic.ElasticMeshManager.submit_resize`,
  ``lane="elastic"``) flush ahead of user-session refreshes within a
  tick: a shrinking fleet must re-place before any user refresh is
  served a placement solved for capacity that no longer exists.  Lane
  occupancy is telemetered per tick (:attr:`TickReport.elastic`).
* **Persistence** — tenant caches snapshot/load as JSON
  (:meth:`OffloadBroker.snapshot` / ``warm_start=`` on
  :meth:`OffloadBroker.register`), so a serving restart replays a known
  workload with *zero* solver dispatches.
* **Telemetry** — per-tick latency, queue depth, coalesce ratio and
  cache hit rate (:class:`BrokerTelemetry`), the numbers a deployment
  would alert on.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Sequence

from repro.core import baselines
from repro.core.cost_models import AppProfile, CostModel, Environment
from repro.core.graph import WCG, WCGBatch
from repro.core.mcop import DEFAULT_BUCKETS, MCOPResult, _bucket_size, mcop_batch
from repro.core.placement_cache import (
    EnvQuantizer,
    PlacementCache,
    profile_fingerprint,
)

__all__ = [
    "PlacementFuture",
    "BrokerReply",
    "TickReport",
    "BrokerTelemetry",
    "OffloadBroker",
]


@dataclasses.dataclass(frozen=True)
class BrokerReply:
    """What a resolved :class:`PlacementFuture` carries.

    ``result`` is clamped (paper §4.3) and priced under the requester's
    exact WCG — identical to what a serial
    :meth:`~repro.core.adaptive.AdaptiveController.observe` would have
    produced.  ``cache_hit`` mirrors the controller's event flag
    (coalesced followers count as hits: the serial loop would have hit
    the representative's just-stored mask).  ``coalesced`` additionally
    distinguishes same-tick followers from genuine cache hits.
    """

    result: MCOPResult
    cache_hit: bool
    coalesced: bool
    tick: int


class PlacementFuture:
    """Minimal single-assignment future resolved by :meth:`OffloadBroker.tick`.

    Deliberately not ``asyncio`` — the broker is deterministic and
    tick-driven, so waiters poll :attr:`done` after a tick rather than
    suspend on an event loop.
    """

    __slots__ = ("_reply",)

    def __init__(self) -> None:
        self._reply: BrokerReply | None = None

    @property
    def done(self) -> bool:
        return self._reply is not None

    def set(self, reply: BrokerReply) -> None:
        if self._reply is not None:
            raise RuntimeError("future already resolved")
        self._reply = reply

    @property
    def result(self) -> BrokerReply:
        if self._reply is None:
            raise RuntimeError("future not resolved yet; run broker.tick()")
        return self._reply


@dataclasses.dataclass(frozen=True)
class TickReport:
    """One tick's telemetry snapshot."""

    tick: int
    queue_depth: int        # requests waiting when the tick started
    requests: int           # requests drained this tick (== queue_depth)
    cache_hits: int         # served from a tenant cache, no solve
    coalesced: int          # same-bin followers folded into another solve
    solved: int             # representative solves actually dispatched
    dispatches: int         # mcop_batch calls (≤ one per shape bucket)
    buckets: tuple[int, ...]  # bucket sizes dispatched this tick
    latency_s: float        # wall time of the tick under the broker clock
    elastic: int = 0        # priority-lane occupancy: elastic events drained


@dataclasses.dataclass
class BrokerTelemetry:
    """Aggregated across ticks; ``reports`` keeps a bounded recent window."""

    ticks: int = 0
    requests: int = 0
    cache_hits: int = 0
    coalesced: int = 0
    solved: int = 0
    dispatches: int = 0
    elastic_requests: int = 0
    max_queue_depth: int = 0
    total_latency_s: float = 0.0
    reports: list[TickReport] = dataclasses.field(default_factory=list)
    keep_reports: int = 256

    def record(self, report: TickReport) -> None:
        self.ticks += 1
        self.requests += report.requests
        self.cache_hits += report.cache_hits
        self.coalesced += report.coalesced
        self.solved += report.solved
        self.dispatches += report.dispatches
        self.elastic_requests += report.elastic
        self.max_queue_depth = max(self.max_queue_depth, report.queue_depth)
        self.total_latency_s += report.latency_s
        self.reports.append(report)
        del self.reports[: -self.keep_reports]

    @property
    def coalesce_ratio(self) -> float:
        """Fraction of requests that did NOT need their own solve."""
        return 1.0 - self.solved / self.requests if self.requests else 0.0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / self.requests if self.requests else 0.0

    @property
    def mean_tick_latency_s(self) -> float:
        return self.total_latency_s / self.ticks if self.ticks else 0.0

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "requests": self.requests,
            "cache_hits": self.cache_hits,
            "coalesced": self.coalesced,
            "solved": self.solved,
            "dispatches": self.dispatches,
            "elastic_requests": self.elastic_requests,
            "max_queue_depth": self.max_queue_depth,
            "coalesce_ratio": round(self.coalesce_ratio, 4),
            "hit_rate": round(self.hit_rate, 4),
            "mean_tick_latency_s": self.mean_tick_latency_s,
        }


@dataclasses.dataclass
class _Tenant:
    name: str
    profile: AppProfile | None
    cost_model: CostModel | None
    cache: PlacementCache
    fingerprint: str | None


# Priority lanes, lowest flushes first.  Elastic fleet events re-place
# before user-session refreshes are served within the same tick.
_LANE_ORDER = {"elastic": 0, "user": 1}


@dataclasses.dataclass
class _Request:
    tenant: _Tenant
    g: WCG | None               # None = deferred: built at tick time from env
    key: tuple[int, ...]
    future: PlacementFuture
    env: Environment | None = None
    lane: str = "user"


class OffloadBroker:
    """Coalescing tick-driven front end over the batched MCOP engine.

    Parameters:
      backend:  MCOP batch backend for the solves ("jax", "pallas",
                "reference" — the latter loops the numpy oracle, used by
                parity tests).
      buckets:  static shape buckets; each tick issues at most one
                ``mcop_batch`` call per bucket, shared across tenants.
      clock:    injectable monotonic clock for tick-latency telemetry
                (tests pass a fake clock so reports are deterministic).
    """

    def __init__(
        self,
        *,
        backend: str = "jax",
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if backend not in ("reference", "jax", "pallas"):
            raise ValueError(f"unknown MCOP batch backend: {backend!r}")
        self.backend = backend
        self.buckets = tuple(buckets)
        self.clock = clock
        self.telemetry = BrokerTelemetry()
        self._tenants: dict[str, _Tenant] = {}
        self._queue: deque[_Request] = deque()
        self._tick = 0

    # -- tenants ---------------------------------------------------------
    def register(
        self,
        name: str,
        profile: AppProfile | None = None,
        cost_model: CostModel | None = None,
        *,
        cache: PlacementCache | None = None,
        quantizer: EnvQuantizer | None = None,
        cache_capacity: int = 4096,
        warm_start=None,
    ) -> _Tenant:
        """Register a served application (or a raw-graph producer).

        With a ``profile`` + ``cost_model`` the tenant accepts
        :meth:`submit`; raw-graph tenants (e.g. the elastic manager,
        whose WCG is built from stage/tier specs) use
        :meth:`submit_graph` and may register with ``profile=None``.
        ``warm_start`` is a snapshot dict or JSON path loaded into the
        tenant cache under the profile's fingerprint guard — a
        mismatched or corrupt snapshot cold-starts silently.
        """
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if (profile is None) != (cost_model is None):
            raise ValueError("profile and cost_model must be given together")
        # the snapshot guard covers the whole (profile, objective) pair: a
        # cache warmed under one cost model must not serve another
        fingerprint = (
            f"{profile_fingerprint(profile)}:{cost_model.fingerprint}"
            if profile is not None
            else None
        )
        if cache is None:
            cache = PlacementCache(quantizer, capacity=cache_capacity)
        tenant = _Tenant(name, profile, cost_model, cache, fingerprint)
        if warm_start is not None:
            cache.load(warm_start, fingerprint=fingerprint)
        self._tenants[name] = tenant
        return tenant

    def tenant(self, name: str) -> _Tenant:
        return self._tenants[name]

    def snapshot(self, name: str) -> dict:
        """Fingerprint-stamped snapshot of one tenant's cache."""
        t = self._tenants[name]
        return t.cache.snapshot(fingerprint=t.fingerprint)

    def save_snapshot(self, name: str, path) -> None:
        t = self._tenants[name]
        t.cache.save(path, fingerprint=t.fingerprint)

    # -- submission ------------------------------------------------------
    def submit(
        self, name: str, env: Environment, *, lane: str = "user"
    ) -> PlacementFuture:
        """Enqueue a solve for ``env`` under the tenant's cost model.

        Construction is deferred: the WCG is built at the next tick, where
        all of this tenant's pending environments go through ONE vectorized
        ``cost_model.build_batch`` call instead of a Python build per
        request.
        """
        t = self._tenants[name]
        if t.profile is None:
            raise ValueError(
                f"tenant {name!r} has no profile; use submit_graph()"
            )
        future = PlacementFuture()
        self._queue.append(
            _Request(t, None, t.cache.key(env), future, env=env, lane=lane)
        )
        return future

    def submit_graph(
        self, name: str, g: WCG, env: Environment, *, lane: str = "user"
    ) -> PlacementFuture:
        """Enqueue a caller-built WCG; ``env`` only determines the bin key."""
        t = self._tenants[name]
        future = PlacementFuture()
        self._queue.append(
            _Request(t, g, t.cache.key(env), future, env=env, lane=lane)
        )
        return future

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- the tick --------------------------------------------------------
    def tick(self) -> TickReport:
        """Drain the queue: lanes → hits → followers → bucket dispatches.

        Elastic-lane requests are flushed ahead of user-lane requests;
        within a lane, FIFO order is preserved, so cache counters and
        placements are bit-identical to N serial controllers sharing one
        cache and observing in submission order (asserted by the
        broker↔serial parity tests).  Deferred (env-only) submissions are
        materialized here, one vectorized cost-model build per tenant.

        Failure containment: if a solve dispatch raises (transient
        device/XLA error), every request whose future is still unresolved
        is put back at the front of the queue before the exception
        propagates, so the next :meth:`tick` retries instead of stranding
        waiters forever.
        """
        t0 = self.clock()
        self._tick += 1
        requests = list(self._queue)
        self._queue.clear()
        requests.sort(key=lambda r: _LANE_ORDER.get(r.lane, 1))  # stable
        try:
            # materialization is inside the containment: a failing deferred
            # build (bad environment) must re-queue innocents, not drop them
            self._materialize(requests)
            return self._run_tick(requests, t0)
        except BaseException:
            self._queue.extendleft(
                r for r in reversed(requests) if not r.future.done
            )
            raise

    def _materialize(self, requests: list[_Request]) -> None:
        """Build deferred WCGs: one ``build_batch`` per tenant per tick.

        Rows of the vectorized build are bit-identical to the scalar
        ``cost_model.build`` (same code path, batch of K), so deferral
        never changes a placement or a reported cost.
        """
        deferred: dict[str, list[_Request]] = {}
        for r in requests:
            if r.g is None:
                deferred.setdefault(r.tenant.name, []).append(r)
        for name, rs in deferred.items():
            t = self._tenants[name]
            batch = t.cost_model.build_batch(t.profile, [r.env for r in rs])
            for i, r in enumerate(rs):
                r.g = batch.wcg(i)

    def _run_tick(self, requests: list[_Request], t0: float) -> TickReport:
        depth = len(requests)
        hits = coalesced = 0
        solves: list[_Request] = []
        # coalescing key includes the vertex count: a raw-graph tenant may
        # legally mix graph sizes in one env bin, and a follower must never
        # be handed a wrong-length mask (mirrors the cache's expected_n)
        rep_slot: dict[tuple[str, int, tuple[int, ...]], int] = {}
        followers: dict[int, list[_Request]] = {}
        for r in requests:
            mask = r.tenant.cache.lookup(r.key, expected_n=r.g.n)
            if mask is not None:
                r.tenant.cache.record(True)
                hits += 1
                r.future.set(
                    BrokerReply(
                        baselines.reprice_clamped(r.g, mask),
                        cache_hit=True,
                        coalesced=False,
                        tick=self._tick,
                    )
                )
                continue
            slot_key = (r.tenant.name, r.g.n, r.key)
            if slot_key in rep_slot:
                coalesced += 1
                followers.setdefault(rep_slot[slot_key], []).append(r)
                continue
            rep_slot[slot_key] = len(solves)
            solves.append(r)

        # one mcop_batch call per static shape bucket, shared across
        # tenants; each bucket is packed into a WCGBatch once, so the
        # dispatch skips the per-graph packing pass
        by_bucket: dict[int, list[int]] = {}
        for i, r in enumerate(solves):
            by_bucket.setdefault(_bucket_size(r.g.n, self.buckets), []).append(i)
        solved: list[MCOPResult | None] = [None] * len(solves)
        dispatches = 0
        for m, idxs in sorted(by_bucket.items()):
            batch = mcop_batch(
                WCGBatch.from_wcgs([solves[i].g for i in idxs], m=m),
                backend=self.backend,
                buckets=(m,),
            )
            dispatches += 1
            for i, res in zip(idxs, batch):
                solved[i] = res

        # counter recording for misses/followers happens here, after the
        # dispatches succeeded: a failed tick re-queues these requests, and
        # the retry must not double-count them (a serial shared-cache loop
        # would count each request exactly once).  Followers count as hits:
        # serially they would have hit the representative's put().
        for slot, r in enumerate(solves):
            candidate = baselines.clamp_no_offloading(r.g, solved[slot])
            r.tenant.cache.record(False)
            r.tenant.cache.store(r.key, candidate.local_mask)
            r.future.set(
                BrokerReply(
                    candidate, cache_hit=False, coalesced=False, tick=self._tick
                )
            )
            for f in followers.get(slot, []):
                f.tenant.cache.record(True)
                f.future.set(
                    BrokerReply(
                        baselines.reprice_clamped(f.g, candidate.local_mask),
                        cache_hit=True,
                        coalesced=True,
                        tick=self._tick,
                    )
                )

        report = TickReport(
            tick=self._tick,
            queue_depth=depth,
            requests=depth,
            cache_hits=hits,
            coalesced=coalesced,
            solved=len(solves),
            dispatches=dispatches,
            buckets=tuple(sorted(by_bucket)),
            latency_s=self.clock() - t0,
            elastic=sum(r.lane == "elastic" for r in requests),
        )
        self.telemetry.record(report)
        return report
