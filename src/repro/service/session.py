"""Per-user adaptive sessions driven through a shared OffloadBroker.

A :class:`BrokerSession` is one user's paper-Fig.-1 loop
(:class:`~repro.core.adaptive.AdaptiveController`) with the *solve*
routed through an :class:`~repro.service.broker.OffloadBroker` instead
of a private ``mcop()`` call.  The controller's
``begin_step``/``commit_step`` split makes this exact: the drift +
cooldown decision (which never depends on solver output) is taken
synchronously at :meth:`BrokerSession.observe`, the placement arrives at
the broker's next tick, and :meth:`BrokerSession.drain` commits events
in observation order — bit-identical to a serial ``observe()`` loop over
controllers sharing one :class:`~repro.core.placement_cache.PlacementCache`
(see the broker↔serial parity tests).

:class:`BatchSessionGroup` is the array-native sibling: K sessions of a
tenant held as one :class:`~repro.core.session_batch.SessionBatch`
pytree and resolved by the broker in ONE vectorized tick — the path the
10⁵–10⁶-user scale benchmarks ride.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.adaptive import AdaptationEvent, AdaptiveController
from repro.core.cost_models import EnvArrays, Environment
from repro.core.graph import WCG
from repro.core.session_batch import SessionBatch, SessionTickReport, tick_sessions
from repro.service.broker import OffloadBroker, PlacementFuture

__all__ = ["BrokerSession", "BatchSessionGroup"]


@dataclasses.dataclass
class _PendingStep:
    g: WCG
    env: Environment
    due: bool
    future: PlacementFuture | None  # None when no repartition was due
    step: int  # controller step at observation time (events carry this)


class BrokerSession:
    """One tenant user: observations in, broker-resolved events out.

    The wrapped controller carries ``cache=None`` — the shared cache
    lives in the broker's tenant and is consulted inside the tick, so
    N sessions of one tenant get the multi-user reuse win without each
    holding cache state.
    """

    def __init__(
        self,
        broker: OffloadBroker,
        tenant: str,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
    ):
        t = broker.tenant(tenant)
        if t.profile is None:
            raise ValueError(f"tenant {tenant!r} has no profile/cost model")
        self.broker = broker
        self.tenant = tenant
        self.controller = AdaptiveController(
            t.profile,
            t.cost_model,
            threshold=threshold,
            min_interval=min_interval,
            backend=broker.backend,
            cache=None,
        )
        self._pending: deque[_PendingStep] = deque()

    def observe(self, env: Environment) -> None:
        """Feed one measurement; enqueues a solve if repartition is due.

        The resulting event materializes at :meth:`drain` after the
        broker's next :meth:`~repro.service.broker.OffloadBroker.tick`.

        If the broker rejects the solve outright (backpressure past the
        scheduler's queued-bin cap), the step degrades to a
        non-repartition: the decision effects are rolled back — exactly
        the containment :meth:`~repro.core.adaptive.AdaptiveController.observe`
        applies on solver failure — so the drift detector retries at the
        next observation, and :meth:`drain` emits the step priced under
        the *current* placement.  A rejection before any placement
        exists raises: the session cannot run without one.
        """
        ctl = self.controller
        checkpoint = ctl.checkpoint_decision()
        g, due = ctl.begin_step(env)
        future = self.broker.submit_graph(self.tenant, g, env) if due else None
        if future is not None and future.done and future.result.rejected:
            ctl.rollback_decision(checkpoint)
            if ctl._current is None:
                raise RuntimeError(
                    f"broker rejected the first placement request of tenant "
                    f"{self.tenant!r} (backpressure); session has no placement "
                    "to fall back on — retry after a tick drains the queue"
                )
            due, future = False, None  # keep the current placement
        self._pending.append(
            _PendingStep(g, env, due, future, ctl._step)
        )

    def drain(self) -> list[AdaptationEvent]:
        """Commit every resolved observation, in order; stops at the
        first one still waiting on a future tick."""
        events: list[AdaptationEvent] = []
        while self._pending:
            step = self._pending[0]
            if step.due and not step.future.done:
                break
            self._pending.popleft()
            if step.due:
                reply = step.future.result
                event = self.controller.commit_step(
                    step.g,
                    step.env,
                    reply.result,
                    repartitioned=True,
                    cache_hit=reply.cache_hit,
                    step=step.step,
                )
            else:
                event = self.controller.commit_step(
                    step.g, step.env, None, repartitioned=False, step=step.step
                )
            events.append(event)
        return events

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def history(self) -> list[AdaptationEvent]:
        return self.controller.history


class BatchSessionGroup:
    """K array-native sessions of one tenant, ticked inside the broker.

    The 10⁵–10⁶-user replacement for K :class:`BrokerSession` objects:
    session state lives in one :class:`~repro.core.session_batch.SessionBatch`
    pytree, a whole tick's observations arrive as one
    :class:`~repro.core.cost_models.EnvArrays`, and the broker's
    :meth:`~repro.service.broker.OffloadBroker.tick` resolves the group
    with ONE :func:`~repro.core.session_batch.tick_sessions` call — same
    shared tenant cache, same coalescing/§4.3 semantics, bit-identical
    events (see the session-batch parity tests).

    Protocol per tick: :meth:`observe` stages the environments (applying
    arrivals/departures first), ``broker.tick()`` runs the batched tick,
    :meth:`drain` returns the accumulated
    :class:`~repro.core.session_batch.SessionTickReport` objects.
    Created via :meth:`OffloadBroker.register_batch`.
    """

    def __init__(
        self,
        broker: OffloadBroker,
        tenant: str,
        *,
        capacity: int,
        threshold: float = 0.10,
        min_interval: int = 1,
        device_telemetry: bool = False,
    ):
        t = broker.tenant(tenant)
        if t.profile is None:
            raise ValueError(f"tenant {tenant!r} has no profile/cost model")
        self.broker = broker
        self.tenant = tenant
        self.device_telemetry = device_telemetry
        self.batch = SessionBatch.create(
            capacity,
            t.profile.n,
            threshold=threshold,
            min_interval=min_interval,
        )
        self._staged: EnvArrays | None = None
        self._reports: deque[SessionTickReport] = deque()

    def observe(
        self,
        envs,
        *,
        arrived=None,
        departed=None,
    ) -> None:
        """Stage one tick of observations for all ``capacity`` slots.

        Args:
          envs:     :class:`EnvArrays` with one row per slot (inactive
                    rows carry placeholders), or a sequence of
                    Environments.
          arrived:  slots (index array or bool mask) activated this tick
                    — reset to fresh sessions before the tick runs.
          departed: slots deactivated this tick (applied before
                    ``arrived``, so a slot can turn over in one tick).

        The staged tick runs at the broker's next
        :meth:`~repro.service.broker.OffloadBroker.tick`; staging twice
        without a tick in between is an error (one batch IS one tick's
        worth of observations).
        """
        if self._staged is not None:
            raise RuntimeError(
                f"batch group {self.tenant!r} already has a staged "
                "observation; run broker.tick() first"
            )
        if departed is not None:
            self.batch.deactivate(departed)
        if arrived is not None:
            self.batch.activate(arrived)
        if not isinstance(envs, EnvArrays):
            envs = EnvArrays.from_envs(envs)
        if envs.k != self.batch.capacity:
            raise ValueError(
                f"envs must carry {self.batch.capacity} rows, got {envs.k}"
            )
        self._staged = envs

    def _tick(self) -> SessionTickReport | None:
        """Run the staged tick (broker-internal).  Atomic: on failure the
        batch state is untouched and the staged envs are kept, so the
        next broker tick retries the whole observation."""
        if self._staged is None:
            return None
        t = self.broker.tenant(self.tenant)
        report = tick_sessions(
            self.batch,
            self._staged,
            profile=t.profile,
            model=t.cost_model,
            cache=t.cache,
            backend=self.broker.backend,
            buckets=self.broker.buckets,
            device_telemetry=self.device_telemetry,
            faults=self.broker.fault_injector,
            resilience=self.broker.resilience,
            tick=self.broker._tick,
            sleep=self.broker._backoff_sleep,
            tracer=self.broker.tracer,
            metrics=self.broker.metrics,
            mesh=self.broker.mesh if self.broker.mesh is not None else False,
        )
        self._staged = None
        self._reports.append(report)
        return report

    def discard_staged(self) -> None:
        """Drop a staged-but-unticked observation (broker shutdown path:
        :meth:`~repro.service.broker.OffloadBroker.drain`)."""
        self._staged = None

    def drain(self) -> list[SessionTickReport]:
        """Return (and clear) the reports of every completed tick."""
        reports = list(self._reports)
        self._reports.clear()
        return reports

    @property
    def pending(self) -> int:
        """Staged-but-unticked observations (0 or 1)."""
        return int(self._staged is not None)

    @property
    def active_sessions(self) -> int:
        return self.batch.active_count
