"""Per-user adaptive sessions driven through a shared OffloadBroker.

A :class:`BrokerSession` is one user's paper-Fig.-1 loop
(:class:`~repro.core.adaptive.AdaptiveController`) with the *solve*
routed through an :class:`~repro.service.broker.OffloadBroker` instead
of a private ``mcop()`` call.  The controller's
``begin_step``/``commit_step`` split makes this exact: the drift +
cooldown decision (which never depends on solver output) is taken
synchronously at :meth:`BrokerSession.observe`, the placement arrives at
the broker's next tick, and :meth:`BrokerSession.drain` commits events
in observation order — bit-identical to a serial ``observe()`` loop over
controllers sharing one :class:`~repro.core.placement_cache.PlacementCache`
(see the broker↔serial parity tests).
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.adaptive import AdaptationEvent, AdaptiveController
from repro.core.cost_models import Environment
from repro.core.graph import WCG
from repro.service.broker import OffloadBroker, PlacementFuture

__all__ = ["BrokerSession"]


@dataclasses.dataclass
class _PendingStep:
    g: WCG
    env: Environment
    due: bool
    future: PlacementFuture | None  # None when no repartition was due
    step: int  # controller step at observation time (events carry this)


class BrokerSession:
    """One tenant user: observations in, broker-resolved events out.

    The wrapped controller carries ``cache=None`` — the shared cache
    lives in the broker's tenant and is consulted inside the tick, so
    N sessions of one tenant get the multi-user reuse win without each
    holding cache state.
    """

    def __init__(
        self,
        broker: OffloadBroker,
        tenant: str,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
    ):
        t = broker.tenant(tenant)
        if t.profile is None:
            raise ValueError(f"tenant {tenant!r} has no profile/cost model")
        self.broker = broker
        self.tenant = tenant
        self.controller = AdaptiveController(
            t.profile,
            t.cost_model,
            threshold=threshold,
            min_interval=min_interval,
            backend=broker.backend,
            cache=None,
        )
        self._pending: deque[_PendingStep] = deque()

    def observe(self, env: Environment) -> None:
        """Feed one measurement; enqueues a solve if repartition is due.

        The resulting event materializes at :meth:`drain` after the
        broker's next :meth:`~repro.service.broker.OffloadBroker.tick`.

        If the broker rejects the solve outright (backpressure past the
        scheduler's queued-bin cap), the step degrades to a
        non-repartition: the decision effects are rolled back — exactly
        the containment :meth:`~repro.core.adaptive.AdaptiveController.observe`
        applies on solver failure — so the drift detector retries at the
        next observation, and :meth:`drain` emits the step priced under
        the *current* placement.  A rejection before any placement
        exists raises: the session cannot run without one.
        """
        ctl = self.controller
        checkpoint = ctl.checkpoint_decision()
        g, due = ctl.begin_step(env)
        future = self.broker.submit_graph(self.tenant, g, env) if due else None
        if future is not None and future.done and future.result.rejected:
            ctl.rollback_decision(checkpoint)
            if ctl._current is None:
                raise RuntimeError(
                    f"broker rejected the first placement request of tenant "
                    f"{self.tenant!r} (backpressure); session has no placement "
                    "to fall back on — retry after a tick drains the queue"
                )
            due, future = False, None  # keep the current placement
        self._pending.append(
            _PendingStep(g, env, due, future, ctl._step)
        )

    def drain(self) -> list[AdaptationEvent]:
        """Commit every resolved observation, in order; stops at the
        first one still waiting on a future tick."""
        events: list[AdaptationEvent] = []
        while self._pending:
            step = self._pending[0]
            if step.due and not step.future.done:
                break
            self._pending.popleft()
            if step.due:
                reply = step.future.result
                event = self.controller.commit_step(
                    step.g,
                    step.env,
                    reply.result,
                    repartitioned=True,
                    cache_hit=reply.cache_hit,
                    step=step.step,
                )
            else:
                event = self.controller.commit_step(
                    step.g, step.env, None, repartitioned=False, step=step.step
                )
            events.append(event)
        return events

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def history(self) -> list[AdaptationEvent]:
        return self.controller.history
