"""Deterministic simulated multi-user workload for the offload broker.

N users walk :class:`~repro.profilers.network.SimulatedChannel`-style
environment traces: each regime has a true (bandwidth, speedup) pair and
observations carry small relative measurement noise, so users in the
same regime land in the same quantized cache bin while the trace still
exercises the drift detector.  Everything is seeded — traces replay
bit-identically, which is what makes the broker's warm-restart claim
testable (same trace + warm cache ⇒ zero solver dispatches) and keeps
the service tests in tier-1.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.adaptive import AdaptationEvent
from repro.core.cost_models import Environment
from repro.service.broker import OffloadBroker
from repro.service.session import BrokerSession

__all__ = [
    "Regime",
    "DEFAULT_REGIMES",
    "environment_trace",
    "user_traces",
    "WorkloadReport",
    "run_workload",
]


@dataclasses.dataclass(frozen=True)
class Regime:
    """One recurring mobile environment (paper §7 scenarios)."""

    name: str
    bandwidth: float  # MB/s, symmetric up/down
    speedup: float    # the paper's F


DEFAULT_REGIMES: tuple[Regime, ...] = (
    Regime("wifi", 8.0, 3.0),
    Regime("lte", 2.5, 3.0),
    Regime("3g", 1.2, 3.0),
    Regime("congested", 0.3, 3.0),
    Regime("cloud-degraded", 0.3, 1.5),
)


def environment_trace(
    steps: int,
    *,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    seed: int = 0,
    dwell: tuple[int, int] = (2, 5),
    rel_noise: float = 0.02,
) -> list[Environment]:
    """One user's seeded walk: dwell in a regime, hop to a neighbor.

    ``rel_noise`` (2% default) is well inside the cache quantizer's 10%
    bins, so repeated visits to a regime hit the same bin — the recurring
    structure the broker exploits — while differing measurements still
    flow through the drift detector.
    """
    rng = np.random.default_rng(seed)
    trace: list[Environment] = []
    regime = int(rng.integers(len(regimes)))
    while len(trace) < steps:
        stay = int(rng.integers(dwell[0], dwell[1] + 1))
        r = regimes[regime]
        for _ in range(min(stay, steps - len(trace))):
            noise_b, noise_f = 1.0 + rel_noise * rng.standard_normal(2)
            trace.append(
                Environment.symmetric(r.bandwidth * noise_b, r.speedup * noise_f)
            )
        # hop to an adjacent regime (environments drift, they don't teleport)
        regime = int(
            np.clip(regime + rng.choice((-1, 1)), 0, len(regimes) - 1)
        )
    return trace


def user_traces(
    n_users: int,
    steps: int,
    *,
    seed: int = 0,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    **kw,
) -> list[list[Environment]]:
    """Per-user traces; user u gets the seeded walk ``seed + u``."""
    return [
        environment_trace(steps, regimes=regimes, seed=seed + u, **kw)
        for u in range(n_users)
    ]


@dataclasses.dataclass
class WorkloadReport:
    """Everything a test or benchmark needs to audit one workload run."""

    events: list[list[AdaptationEvent]]   # [user][step]
    traces: list[list[Environment]]       # the envs that were replayed
    ticks: int

    @property
    def n_repartitions(self) -> int:
        return sum(e.repartitioned for evs in self.events for e in evs)

    @property
    def n_cache_hits(self) -> int:
        return sum(e.cache_hit for evs in self.events for e in evs)


def run_workload(
    broker: OffloadBroker,
    tenant: str,
    *,
    n_users: int,
    steps: int,
    threshold: float = 0.15,
    min_interval: int = 2,
    seed: int = 0,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    traces: Sequence[Sequence[Environment]] | None = None,
) -> WorkloadReport:
    """Drive N users through the broker, one tick per timestep.

    Per tick every user observes its next environment (enqueuing solves
    for due repartitions), the broker flushes once, and sessions drain —
    the serving loop in miniature.  Pass ``traces`` to replay a known
    workload (e.g. against a warm-started broker); otherwise seeded
    traces are generated with :func:`user_traces`.
    """
    if traces is None:
        traces = user_traces(
            n_users, steps, seed=seed, regimes=regimes
        )
    else:
        traces = [list(t) for t in traces]
        if len(traces) != n_users or any(len(t) != steps for t in traces):
            raise ValueError("traces must be n_users × steps")
    sessions = [
        BrokerSession(
            broker, tenant, threshold=threshold, min_interval=min_interval
        )
        for _ in range(n_users)
    ]
    events: list[list[AdaptationEvent]] = [[] for _ in range(n_users)]
    for t in range(steps):
        for session, trace in zip(sessions, traces):
            session.observe(trace[t])
        broker.tick()
        for u, session in enumerate(sessions):
            events[u].extend(session.drain())
    assert all(s.pending == 0 for s in sessions)
    return WorkloadReport(events=events, traces=traces, ticks=steps)
