"""Deterministic simulated multi-user workload for the offload broker.

N users walk :class:`~repro.profilers.network.SimulatedChannel`-style
environment traces: each regime has a true (bandwidth, speedup) pair and
observations carry small relative measurement noise, so users in the
same regime land in the same quantized cache bin while the trace still
exercises the drift detector.  Everything is seeded — traces replay
bit-identically, which is what makes the broker's warm-restart claim
testable (same trace + warm cache ⇒ zero solver dispatches) and keeps
the service tests in tier-1.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.adaptive import AdaptationEvent
from repro.core.cost_models import EnvArrays, Environment
from repro.core.session_batch import SessionTickReport
from repro.service.broker import OffloadBroker
from repro.service.session import BatchSessionGroup, BrokerSession

__all__ = [
    "Regime",
    "DEFAULT_REGIMES",
    "environment_trace",
    "user_traces",
    "WorkloadReport",
    "run_workload",
    "TrafficTick",
    "TrafficGenerator",
    "run_batch_workload",
]


@dataclasses.dataclass(frozen=True)
class Regime:
    """One recurring mobile environment (paper §7 scenarios)."""

    name: str
    bandwidth: float  # MB/s, symmetric up/down
    speedup: float    # the paper's F


DEFAULT_REGIMES: tuple[Regime, ...] = (
    Regime("wifi", 8.0, 3.0),
    Regime("lte", 2.5, 3.0),
    Regime("3g", 1.2, 3.0),
    Regime("congested", 0.3, 3.0),
    Regime("cloud-degraded", 0.3, 1.5),
)


def environment_trace(
    steps: int,
    *,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    seed: int = 0,
    dwell: tuple[int, int] = (2, 5),
    rel_noise: float = 0.02,
) -> list[Environment]:
    """One user's seeded walk: dwell in a regime, hop to a neighbor.

    ``rel_noise`` (2% default) is well inside the cache quantizer's 10%
    bins, so repeated visits to a regime hit the same bin — the recurring
    structure the broker exploits — while differing measurements still
    flow through the drift detector.
    """
    rng = np.random.default_rng(seed)
    trace: list[Environment] = []
    regime = int(rng.integers(len(regimes)))
    while len(trace) < steps:
        stay = int(rng.integers(dwell[0], dwell[1] + 1))
        r = regimes[regime]
        for _ in range(min(stay, steps - len(trace))):
            noise_b, noise_f = 1.0 + rel_noise * rng.standard_normal(2)
            trace.append(
                Environment.symmetric(r.bandwidth * noise_b, r.speedup * noise_f)
            )
        # hop to an adjacent regime (environments drift, they don't teleport)
        regime = int(
            np.clip(regime + rng.choice((-1, 1)), 0, len(regimes) - 1)
        )
    return trace


def user_traces(
    n_users: int,
    steps: int,
    *,
    seed: int = 0,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    **kw,
) -> list[list[Environment]]:
    """Per-user traces; user u gets the seeded walk ``seed + u``."""
    return [
        environment_trace(steps, regimes=regimes, seed=seed + u, **kw)
        for u in range(n_users)
    ]


@dataclasses.dataclass
class WorkloadReport:
    """Everything a test or benchmark needs to audit one workload run."""

    events: list[list[AdaptationEvent]]   # [user][step]
    traces: list[list[Environment]]       # the envs that were replayed
    ticks: int

    @property
    def n_repartitions(self) -> int:
        return sum(e.repartitioned for evs in self.events for e in evs)

    @property
    def n_cache_hits(self) -> int:
        return sum(e.cache_hit for evs in self.events for e in evs)


def run_workload(
    broker: OffloadBroker,
    tenant: str,
    *,
    n_users: int,
    steps: int,
    threshold: float = 0.15,
    min_interval: int = 2,
    seed: int = 0,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    traces: Sequence[Sequence[Environment]] | None = None,
) -> WorkloadReport:
    """Drive N users through the broker, one tick per timestep.

    Per tick every user observes its next environment (enqueuing solves
    for due repartitions), the broker flushes once, and sessions drain —
    the serving loop in miniature.  Pass ``traces`` to replay a known
    workload (e.g. against a warm-started broker); otherwise seeded
    traces are generated with :func:`user_traces`.
    """
    if traces is None:
        traces = user_traces(
            n_users, steps, seed=seed, regimes=regimes
        )
    else:
        traces = [list(t) for t in traces]
        if len(traces) != n_users or any(len(t) != steps for t in traces):
            raise ValueError("traces must be n_users × steps")
    sessions = [
        BrokerSession(
            broker, tenant, threshold=threshold, min_interval=min_interval
        )
        for _ in range(n_users)
    ]
    events: list[list[AdaptationEvent]] = [[] for _ in range(n_users)]
    for t in range(steps):
        for session, trace in zip(sessions, traces):
            session.observe(trace[t])
        broker.tick()
        for u, session in enumerate(sessions):
            events[u].extend(session.drain())
    assert all(s.pending == 0 for s in sessions)
    return WorkloadReport(events=events, traces=traces, ticks=steps)


# ----------------------------------------------------------------------
# Array-native traffic: Poisson arrivals + geometric churn over a fixed
# capacity of session slots, vectorized regime walks — the 10⁵–10⁶-user
# feed for BatchSessionGroup ticks.
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficTick:
    """One tick of generated traffic over all capacity slots.

    ``envs`` carries a row for every slot — inactive rows hold a
    harmless placeholder environment (bandwidth = speedup = 1) that the
    batched tick prices but never acts on (inactive sessions are never
    due).  ``arrived``/``departed`` are this tick's churn, already
    reflected in ``active``.
    """

    envs: EnvArrays
    active: np.ndarray    # (capacity,) bool — live after this tick's churn
    arrived: np.ndarray   # (capacity,) bool — slots activated this tick
    departed: np.ndarray  # (capacity,) bool — slots freed this tick


class TrafficGenerator:
    """Seeded vectorized traffic source for a fixed-capacity slot pool.

    Per :meth:`step`, in order: geometric churn (each live session
    departs with probability ``churn``), Poisson(``arrival_rate``)
    arrivals filling the lowest free slots (plus ``initial`` sessions on
    the first step), then one vectorized regime-walk update — ongoing
    sessions count down a dwell timer and hop to an adjacent regime when
    it expires, mirroring :func:`environment_trace`'s walk, and
    observations carry the same 2% relative measurement noise.

    Determinism: every random draw is a fixed-size (capacity,) array
    each step, so the generated traffic is a pure function of
    ``(seed, capacity, step)`` — independent of how occupancy evolves —
    and replays bit-identically (asserted by the churn determinism
    test).
    """

    def __init__(
        self,
        capacity: int,
        *,
        seed: int = 0,
        regimes: Sequence[Regime] = DEFAULT_REGIMES,
        arrival_rate: float = 1.0,
        churn: float = 0.05,
        initial: int | None = None,
        dwell: tuple[int, int] = (2, 5),
        rel_noise: float = 0.02,
    ):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if not (0.0 <= churn < 1.0):
            raise ValueError("churn must be in [0, 1)")
        self.capacity = int(capacity)
        self.regimes = tuple(regimes)
        self.arrival_rate = float(arrival_rate)
        self.churn = float(churn)
        self.initial = (
            min(int(initial), capacity) if initial is not None else capacity // 2
        )
        self.dwell = (int(dwell[0]), int(dwell[1]))
        self.rel_noise = float(rel_noise)
        self.rng = np.random.default_rng(seed)
        self._band = np.array([r.bandwidth for r in self.regimes])
        self._speed = np.array([r.speedup for r in self.regimes])
        self._active = np.zeros(self.capacity, dtype=bool)
        self._regime = np.zeros(self.capacity, dtype=np.int64)
        self._dwell_left = np.zeros(self.capacity, dtype=np.int64)
        self._step = 0

    def step(self) -> TrafficTick:
        cap, rng = self.capacity, self.rng
        n_regimes = len(self.regimes)

        # geometric churn: each live session departs with prob `churn`
        departed = self._active & (rng.random(cap) < self.churn)
        self._active &= ~departed

        # Poisson arrivals fill the lowest free slots (first step also
        # seeds `initial` sessions so the pool starts warm)
        n_arrivals = int(rng.poisson(self.arrival_rate))
        if self._step == 0:
            n_arrivals += self.initial
        free = np.nonzero(~self._active)[0]
        arrived = np.zeros(cap, dtype=bool)
        arrived[free[:n_arrivals]] = True

        # fixed-size draws keep the stream occupancy-independent
        arr_regime = rng.integers(n_regimes, size=cap)
        arr_dwell = rng.integers(self.dwell[0], self.dwell[1] + 1, size=cap)
        hop_dir = rng.choice((-1, 1), size=cap)
        hop_dwell = rng.integers(self.dwell[0], self.dwell[1] + 1, size=cap)
        noise = 1.0 + self.rel_noise * rng.standard_normal((cap, 2))

        self._regime = np.where(arrived, arr_regime, self._regime)
        self._dwell_left = np.where(arrived, arr_dwell, self._dwell_left)
        self._active |= arrived

        # ongoing sessions walk: dwell counts down, expiry hops ±1 regime
        ongoing = self._active & ~arrived
        self._dwell_left = np.where(
            ongoing, self._dwell_left - 1, self._dwell_left
        )
        hop = ongoing & (self._dwell_left <= 0)
        self._regime = np.where(
            hop,
            np.clip(self._regime + hop_dir, 0, n_regimes - 1),
            self._regime,
        )
        self._dwell_left = np.where(hop, hop_dwell, self._dwell_left)

        band = np.where(
            self._active, self._band[self._regime] * noise[:, 0], 1.0
        )
        speed = np.where(
            self._active, self._speed[self._regime] * noise[:, 1], 1.0
        )
        envs = EnvArrays(
            bandwidth_up=band,
            bandwidth_down=band.copy(),
            speedup=speed,
            p_compute=np.full(cap, 0.9),
            p_idle=np.full(cap, 0.3),
            p_transfer=np.full(cap, 1.3),
        )
        self._step += 1
        return TrafficTick(
            envs=envs,
            active=self._active.copy(),
            arrived=arrived,
            departed=departed,
        )


def run_batch_workload(
    broker: OffloadBroker,
    group: BatchSessionGroup,
    *,
    steps: int,
    seed: int = 0,
    regimes: Sequence[Regime] = DEFAULT_REGIMES,
    arrival_rate: float = 1.0,
    churn: float = 0.05,
    initial: int | None = None,
) -> list[SessionTickReport]:
    """Drive a batch session group through seeded churning traffic.

    The batched sibling of :func:`run_workload`: one
    :class:`TrafficGenerator` step stages the whole pool's observations
    (arrivals and departures included), one ``broker.tick()`` resolves
    them.  Returns the per-tick
    :class:`~repro.core.session_batch.SessionTickReport` list.
    """
    gen = TrafficGenerator(
        group.batch.capacity,
        seed=seed,
        regimes=regimes,
        arrival_rate=arrival_rate,
        churn=churn,
        initial=initial,
    )
    for _ in range(steps):
        tick = gen.step()
        group.observe(tick.envs, arrived=tick.arrived, departed=tick.departed)
        broker.tick()
    return group.drain()
