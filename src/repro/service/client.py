"""Client side of the cross-process serving plane.

:class:`BrokerClient` connects to a :class:`~repro.service.server.SolverServer`
over a unix or TCP socket and duck-types the slice of
:class:`~repro.service.broker.OffloadBroker` that
:class:`~repro.service.session.BrokerSession` consumes — ``backend``,
``tenant()``, ``submit_graph()`` — so the *existing* session class runs
unchanged against a remote solver:

    client = BrokerClient(unix_address(sock), tenants={"app": (profile, cm)})
    client.connect()
    session = BrokerSession(client, "app")   # unmodified class
    session.observe(env); client.tick(); session.drain()

Determinism: ``submit_graph`` ships only the six-scalar environment —
the server's deferred-build path reconstructs the WCG from its own copy
of the profile bit-identically (the in-process broker already relies on
this equivalence), and JSON float64 round-trips are exact, so a
cross-process session's events ``==`` an in-process session's.

Resilience across the socket (PR 7's machinery, one layer up):

* **Graceful reconnect** — any transport failure (ECONNRESET, EOF
  mid-frame, a poisoned stream) tears the socket down and redials under
  the client's :class:`~repro.service.resilience.RetryPolicy`; backoff
  sleeps go through the injected clock so tests advance time instead of
  waiting.
* **Idempotent resubmission** — every submit carries a client-unique
  request id and is remembered until its reply lands.  After a
  reconnect (including against a *restarted, warm-started* server) the
  unresolved window is resubmitted verbatim; the server's reply log and
  inflight dedup make this safe — replayed ids are acknowledged with
  ``replayed=True`` and never double-count cache stats.

Every frame exchange runs under a ``wire.frame`` tracer span with
``transport``/``type`` labels, mirroring the server side, so a
cross-process trace shows both halves of each round trip.
"""

from __future__ import annotations

import os
import socket
import time
from typing import Callable

from repro.core.cost_models import Environment
from repro.obs.trace import NULL_SPAN, Tracer
from repro.service.resilience import RetryPolicy
from repro.service.wire import (
    DEFAULT_MAX_FRAME,
    PROTOCOL_VERSION,
    FrameStream,
    RemoteError,
    TruncatedFrame,
    VersionMismatch,
    WireError,
    env_to_wire,
    supported_encodings,
    wire_to_reply,
)

__all__ = ["BrokerClient", "ClientFuture", "RemoteBatchGroup", "connect"]


class ClientFuture:
    """Client-side :class:`~repro.service.broker.PlacementFuture` twin:
    resolved when the server's reply frame for its request id arrives
    (usually during :meth:`BrokerClient.tick`)."""

    __slots__ = ("id", "_reply")

    def __init__(self, rid: str):
        self.id = rid
        self._reply = None

    @property
    def done(self) -> bool:
        return self._reply is not None

    def set(self, reply) -> None:
        if self._reply is not None:
            raise RuntimeError(f"future {self.id} already resolved")
        self._reply = reply

    @property
    def result(self):
        if self._reply is None:
            raise RuntimeError(
                f"future {self.id} not resolved yet; run client.tick()"
            )
        return self._reply


class _RemoteTenant:
    """What ``BrokerSession`` reads off ``broker.tenant(name)`` — the
    client-local copy of the tenant's profile + cost model."""

    __slots__ = ("name", "profile", "cost_model")

    def __init__(self, name, profile, cost_model):
        self.name = name
        self.profile = profile
        self.cost_model = cost_model


class RemoteBatchGroup:
    """Proxy for a server-side :class:`~repro.service.session.BatchSessionGroup`.

    ``observe`` stages one tick of per-session environment arrays on the
    server; the group is resolved inside the server's next broker tick
    and its summary arrives as a ``batch_report`` frame, surfaced here
    by :meth:`drain` as plain dicts (``active``/``due``/``hits``/
    ``solved``/``coalesced``/``degraded``/``min_cut``/``gain``).
    """

    def __init__(self, client: "BrokerClient", gid: str, capacity: int):
        self.client = client
        self.id = gid
        self.capacity = capacity
        self._reports: list[dict] = []

    def observe(self, envs, *, arrived=None, departed=None) -> None:
        frame = {
            "type": "observe_batch",
            "group": self.id,
            "envs": {
                f: [float(v) for v in getattr(envs, f)]
                for f in type(envs)._fields
            },
        }
        if arrived is not None:
            frame["arrived"] = [int(i) for i in arrived]
        if departed is not None:
            frame["departed"] = [int(i) for i in departed]
        self.client._call(frame, "observe_ok")

    def drain(self) -> list[dict]:
        reports = self._reports
        self._reports = []
        return reports


class BrokerClient:
    """One connection to a remote solver; N sessions ride on it.

    Parameters:
      address:  ``("unix", path)`` or ``("tcp", host, port)``.
      tenants:  name → ``(profile, cost_model)`` — the client-local
                tenant metadata sessions need.  Must mirror the server's
                registration (the hello handshake cross-checks names).
      client:   name stamped on request ids and trace spans; defaults
                to ``pid<os.getpid()>``.
      encoding: proposed wire encoding; the server may fall back to
                ``"json"``.
      retry:    reconnect policy (attempts + backoff); default
                ``RetryPolicy()``.
      timeout:  per-read socket timeout — no reply can hang forever.
      sleep/clock: injectable for deterministic tests: ``sleep`` is
                called with each backoff (tests pass
                ``InjectedClock().advance``), ``clock`` timestamps
                spans only.
    """

    def __init__(
        self,
        address: tuple,
        *,
        tenants: dict | None = None,
        client: str | None = None,
        encoding: str = "json",
        max_frame: int = DEFAULT_MAX_FRAME,
        retry: RetryPolicy | None = None,
        timeout: float = 30.0,
        tracer: Tracer | None = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.monotonic,
    ):
        if address[0] not in ("unix", "tcp"):
            raise ValueError(f"unknown address family {address[0]!r}")
        if encoding not in supported_encodings():
            raise ValueError(f"encoding {encoding!r} not available here")
        self.address = address
        self.transport = address[0]
        self.name = client if client is not None else f"pid{os.getpid()}"
        self.encoding = encoding
        self.max_frame = int(max_frame)
        self.retry = retry if retry is not None else RetryPolicy()
        self.timeout = float(timeout)
        self.tracer = tracer
        self._sleep = sleep
        self.clock = clock
        self._tenants: dict[str, _RemoteTenant] = {}
        for tname, (profile, cm) in (tenants or {}).items():
            self._tenants[tname] = _RemoteTenant(tname, profile, cm)
        self._stream: FrameStream | None = None
        self.backend: str | None = None
        self.server_tenants: tuple[str, ...] = ()
        self.server_tick: int = 0
        self._seq = 0
        # id → ClientFuture plus the submit frame to replay on reconnect
        self._unresolved: dict[str, ClientFuture] = {}
        self._submits: dict[str, dict] = {}
        self._groups: dict[str, RemoteBatchGroup] = {}
        self.reconnects = 0
        self.resubmitted = 0

    # -- the OffloadBroker surface BrokerSession consumes ---------------
    def tenant(self, name: str) -> _RemoteTenant:
        return self._tenants[name]

    def submit_graph(self, name: str, g, env: Environment) -> ClientFuture:
        """Session-facing submit: the graph is dropped on the floor —
        the server rebuilds it from its own profile copy, bit-identically
        (same deferred-build path the in-process broker uses)."""
        return self.submit(name, env)

    # -- connection lifecycle -------------------------------------------
    def _span(self, name: str, **attrs):
        return (
            self.tracer.span(name, **attrs)
            if self.tracer is not None
            else NULL_SPAN
        )

    def _dial(self) -> FrameStream:
        if self.transport == "unix":
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self.timeout)
            sock.connect(self.address[1])
        else:
            sock = socket.create_connection(
                (self.address[1], self.address[2]), timeout=self.timeout
            )
        stream = FrameStream(
            sock, encoding="json", max_frame=self.max_frame
        )
        stream.send(
            {
                "type": "hello",
                "version": PROTOCOL_VERSION,
                "encoding": self.encoding,
                "client": self.name,
            }
        )
        frame = stream.recv(self.timeout)
        if frame is None:
            raise TruncatedFrame("server closed during handshake")
        if frame["type"] == "error":
            stream.close()
            if frame.get("code") == "version_mismatch":
                raise VersionMismatch(frame.get("message", ""))
            raise RemoteError(frame.get("code", "server_error"),
                              frame.get("message", ""))
        if frame["type"] != "hello_ok":
            stream.close()
            raise RemoteError("server_error",
                              f"expected hello_ok, got {frame['type']!r}")
        stream.encoding = frame.get("encoding", "json")
        self.backend = frame.get("backend")
        self.server_tenants = tuple(frame.get("tenants", ()))
        self.server_tick = int(frame.get("tick", 0))
        missing = [t for t in self._tenants if t not in self.server_tenants]
        if missing:
            stream.close()
            raise RemoteError(
                "unknown_tenant",
                f"server is missing tenants {missing}",
            )
        return stream

    def connect(self) -> "BrokerClient":
        """Dial + hello handshake (idempotent).  A dial onto a fresh
        connection always replays the unresolved submit window — the
        server dedups, so this is free on a live server and exactly what
        a warm-restarted one needs."""
        if self._stream is None:
            with self._span(
                "wire.connect", transport=self.transport, client=self.name
            ):
                self._stream = self._dial()
                self._resubmit_window()
        return self

    def close(self) -> None:
        if self._stream is not None:
            try:
                self._stream.send({"type": "bye"})
            except (OSError, WireError):
                pass
            self._stream.close()
            self._stream = None

    def _drop_stream(self) -> None:
        if self._stream is not None:
            self._stream.close()
            self._stream = None

    def _reconnect(self) -> None:
        """Redial under the retry policy, then replay the unresolved
        submit window (the server dedups replayed ids)."""
        last: Exception | None = None
        for attempt in range(self.retry.attempts):
            try:
                self._stream = self._dial()
                break
            except (OSError, TruncatedFrame) as err:
                last = err
                self._drop_stream()
                if attempt + 1 < self.retry.attempts:
                    self._sleep(self.retry.backoff(attempt))
        else:
            raise ConnectionError(
                f"reconnect to {self.address} failed after "
                f"{self.retry.attempts} attempts"
            ) from last
        self.reconnects += 1
        self._resubmit_window()

    def _resubmit_window(self) -> None:
        """Replay every unresolved submit on the current connection.
        Idempotent server-side: known ids are acked ``replayed=True``
        (already-resolved ones push their stored reply first) without
        touching the journal, the queue, or the cache counters."""
        for rid in list(self._unresolved):
            frame = self._submits.get(rid)
            if frame is None:
                continue
            self._stream.send(frame)
            self.resubmitted += 1
            self._await("submit_ok", id=rid)

    # -- frame plumbing --------------------------------------------------
    def _dispatch(self, frame: dict) -> None:
        """Route an asynchronous server push (reply / batch_report)."""
        ftype = frame["type"]
        if ftype == "reply":
            fut = self._unresolved.pop(frame.get("id"), None)
            self._submits.pop(frame.get("id"), None)
            if fut is not None and not fut.done:
                fut.set(wire_to_reply(frame))
        elif ftype == "batch_report":
            group = self._groups.get(frame.get("group"))
            if group is not None:
                group._reports.append(frame)
        elif ftype == "error":
            raise RemoteError(
                frame.get("code", "server_error"), frame.get("message", "")
            )

    def _await(self, expect: str, **match) -> dict:
        """Read frames (dispatching pushes) until one of type ``expect``
        whose fields match ``match`` arrives."""
        while True:
            frame = self._stream.recv(self.timeout)
            if frame is None:
                raise TruncatedFrame("server closed the connection")
            if frame["type"] == expect and all(
                frame.get(k) == v for k, v in match.items()
            ):
                return frame
            self._dispatch(frame)

    def _call(self, frame: dict, expect: str, **match) -> dict:
        """One request/response round trip with transparent reconnect.

        A transport failure mid-call redials and retries the call once
        on the fresh connection — safe because every mutating frame is
        idempotent on the server (journaled ids dedup, ticks are
        client-driven and a torn tick frame was either applied or not;
        the retried tick then simply runs the next tick, which the
        caller was about to request anyway).
        """
        self.connect()
        with self._span(
            "wire.frame",
            type=frame["type"],
            transport=self.transport,
            client=self.name,
        ):
            try:
                self._stream.send(frame)
                return self._await(expect, **match)
            except (OSError, TruncatedFrame):
                self._drop_stream()
                self._reconnect()
                self._stream.send(frame)
                return self._await(expect, **match)

    # -- serving API -----------------------------------------------------
    def submit(
        self,
        name: str,
        env: Environment,
        *,
        lane: str = "user",
        deadline: int | None = None,
    ) -> ClientFuture:
        """Remote :meth:`~repro.service.broker.OffloadBroker.submit`:
        returns a future resolved by a later :meth:`tick`.  The ack is
        synchronous — once this returns, the request is journaled
        server-side and survives a solver crash."""
        if name not in self._tenants:
            raise KeyError(f"tenant {name!r} not configured on this client")
        self._seq += 1
        rid = f"{self.name}-{self._seq}"
        frame = {
            "type": "submit",
            "id": rid,
            "tenant": name,
            "env": env_to_wire(env),
            "lane": lane,
            "deadline": deadline,
        }
        fut = ClientFuture(rid)
        self._unresolved[rid] = fut
        self._submits[rid] = frame
        self._call(frame, "submit_ok", id=rid)
        # a rejected/replayed submit may already have pushed the reply
        return fut

    def tick(self, *, budget: int | None = None) -> dict:
        """Drive one broker tick; replies for every request resolved by
        it are dispatched into their futures before this returns.

        Exactly-once across crashes: a tick frame is NOT blindly
        replayed after a reconnect.  The client remembers the server
        tick it expects to drive; if the hello of the fresh connection
        (to a warm-restarted server whose journal replay re-ran the
        interrupted tick) already shows that tick, the call returns a
        synthetic ``tick_report`` instead of burning an extra tick —
        keeping reply tick numbers aligned with an uninterrupted run,
        whichever side of the journal append the crash landed on.
        """
        expected = self.server_tick + 1
        frame: dict = {"type": "tick"}
        if budget is not None:
            frame["budget"] = budget

        def already_ran() -> dict:
            return {"type": "tick_report", "tick": self.server_tick,
                    "replayed": True}

        self.connect()
        if self.server_tick >= expected:
            # a reconnect (here or in a failed earlier call) landed on a
            # server that already ran this tick — don't run another
            return already_ran()
        with self._span(
            "wire.frame", type="tick", transport=self.transport,
            client=self.name,
        ):
            try:
                self._stream.send(frame)
                report = self._await("tick_report")
            except (OSError, TruncatedFrame):
                self._drop_stream()
                self._reconnect()
                if self.server_tick >= expected:
                    return already_ran()
                self._stream.send(frame)
                report = self._await("tick_report")
        self.server_tick = int(report.get("tick", self.server_tick))
        return report

    def drain(self, *, max_ticks: int = 1024) -> int:
        """Tick until every outstanding future is resolved (the remote
        analogue of :meth:`OffloadBroker.drain`).  Returns ticks run."""
        ran = 0
        while self._unresolved and ran < max_ticks:
            self.tick()
            ran += 1
        if self._unresolved:
            raise RuntimeError(
                f"{len(self._unresolved)} futures unresolved after "
                f"{ran} ticks"
            )
        return ran

    def register_batch(
        self,
        name: str,
        capacity: int,
        *,
        threshold: float = 0.10,
        min_interval: int = 1,
    ) -> RemoteBatchGroup:
        """Attach a server-side batch session group; returns its proxy."""
        ok = self._call(
            {
                "type": "register_batch",
                "tenant": name,
                "capacity": int(capacity),
                "threshold": float(threshold),
                "min_interval": int(min_interval),
            },
            "register_ok",
        )
        group = RemoteBatchGroup(self, ok["group"], int(capacity))
        self._groups[ok["group"]] = group
        return group

    def telemetry(self, *, metrics: bool = False) -> dict:
        """Server-side broker telemetry summary (+ cache stats, and the
        metrics-registry snapshot when ``metrics=True``)."""
        return self._call({"type": "telemetry", "metrics": metrics},
                          "telemetry_report")

    def snapshot(self) -> int:
        """Force a server snapshot pass; returns the covered journal seq."""
        return int(self._call({"type": "snapshot"}, "snapshot_ok")["seq"])

    def ping(self) -> None:
        """Liveness probe + flush barrier."""
        self._seq += 1
        nonce = f"{self.name}-ping-{self._seq}"
        self._call({"type": "ping", "nonce": nonce}, "pong", nonce=nonce)

    @property
    def unresolved(self) -> int:
        return len(self._unresolved)

    def __enter__(self) -> "BrokerClient":
        return self.connect()

    def __exit__(self, *exc) -> None:
        self.close()


def connect(address: tuple, **kwargs) -> BrokerClient:
    """``BrokerClient(address, **kwargs).connect()`` in one call."""
    return BrokerClient(address, **kwargs).connect()
