"""Length-prefixed frame protocol for the cross-process serving plane.

The broker tick machinery has been transport-agnostic since PR 3; this
module is the transport.  A *frame* is one protocol message:

    +----------------+-----------+----------------------+
    | 4B big-endian  | 1B        | payload              |
    | payload length | encoding  | (json or msgpack)    |
    +----------------+-----------+----------------------+

The payload decodes to a dict carrying a ``"type"`` key.  Frame types:

==================  ==================================================
``hello``           client → server: protocol ``version``, proposed
                    ``encoding``, ``client`` name.
``hello_ok``        server → client: accepted ``version``/``encoding``,
                    broker ``backend``, registered ``tenants``,
                    ``max_frame``, supported ``encodings``.
``submit``          client → server: ``id`` (request id), ``tenant``,
                    ``env`` (six float64 scalars), ``lane``,
                    optional ``deadline`` (ticks).
``submit_ok``       server → client: ``id`` journaled and queued
                    (``replayed=True`` when the id was already known —
                    the idempotent-resubmission ack).
``reply``           server → client: resolved
                    :class:`~repro.service.broker.BrokerReply` for
                    ``id`` (``min_cut`` + ``local_mask`` + flags).
``tick``            client → server: run one broker tick.
``tick_report``     server → client: the tick's
                    :class:`~repro.service.broker.TickReport` summary.
``observe_batch``   client → server: stage one tick of EnvArrays rows
                    on a server-side batch session group.
``batch_report``    server → client: the group's per-tick summary.
``telemetry``       client → server: request telemetry;
``telemetry_report``server → client: broker telemetry summary +
                    cache stats + optional metrics-registry snapshot.
``snapshot``        client → server: force a snapshot pass now.
``snapshot_ok``     server → client: snapshot written (``seq``).
``ping``/``pong``   liveness + flush barrier (a ``pong`` proves every
                    earlier pushed frame was delivered).
``error``           either direction: typed failure — ``code`` below.
``bye``             client → server: clean close.
==================  ==================================================

Error codes (``ERROR_CODES``): ``version_mismatch``, ``bad_frame``,
``too_large``, ``unknown_type``, ``unknown_tenant``, ``unknown_group``,
``bad_request``, ``not_ready``, ``server_error``.  Framing-level errors
(``bad_frame``/``too_large``) poison the byte stream — the peer sends a
best-effort error frame and disconnects, because there is no way to
resynchronize on a corrupt length prefix.  Frame-content errors
(``unknown_*``/``bad_request``) keep the connection open.

Determinism contract: JSON float64 round-trips are exact (shortest
round-trip repr), so an :class:`~repro.core.cost_models.Environment`
or a reply's ``min_cut`` crossing the wire is BIT-identical on both
sides — what makes the cross-process parity and crash-recovery tests
``==``-exact.  msgpack (optional, negotiated at hello) carries float64
natively and is exact too.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Tuple

import numpy as np

from repro.core.cost_models import Environment
from repro.core.mcop import MCOPResult

try:  # optional wire encoding; JSON is always available
    import msgpack as _msgpack

    HAVE_MSGPACK = True
except ModuleNotFoundError:  # pragma: no cover — minimal container
    _msgpack = None
    HAVE_MSGPACK = False

__all__ = [
    "PROTOCOL_VERSION",
    "DEFAULT_MAX_FRAME",
    "ENCODINGS",
    "ERROR_CODES",
    "WireError",
    "BadFrame",
    "FrameTooLarge",
    "TruncatedFrame",
    "VersionMismatch",
    "RemoteError",
    "encode_frame",
    "decode_frame",
    "FrameStream",
    "env_to_wire",
    "wire_to_env",
    "reply_to_wire",
    "wire_to_reply",
    "error_frame",
    "supported_encodings",
]

PROTOCOL_VERSION = 1

# 4-byte length + 1-byte encoding tag
_HEADER = struct.Struct("!IB")
HEADER_SIZE = _HEADER.size

# A frame larger than this is refused on both encode and decode: the
# serving plane moves scalars and (n,)-bool masks, never tensors, so a
# multi-megabyte frame is a protocol violation, not a big request.
DEFAULT_MAX_FRAME = 1 << 20

ENCODINGS = {"json": 0, "msgpack": 1}
_ENCODING_NAMES = {v: k for k, v in ENCODINGS.items()}

ERROR_CODES = (
    "version_mismatch",
    "bad_frame",
    "too_large",
    "unknown_type",
    "unknown_tenant",
    "unknown_group",
    "bad_request",
    "not_ready",
    "server_error",
)


def supported_encodings() -> tuple[str, ...]:
    """Encodings this process can decode (JSON always; msgpack when
    the optional dependency is importable)."""
    return ("json", "msgpack") if HAVE_MSGPACK else ("json",)


class WireError(Exception):
    """Base protocol failure; ``code`` names the typed error frame the
    peer should see."""

    code = "bad_frame"


class BadFrame(WireError):
    """Undecodable payload, unknown encoding tag, or a non-dict frame."""

    code = "bad_frame"


class FrameTooLarge(WireError):
    """Declared (or would-be encoded) length past the max-frame bound."""

    code = "too_large"


class TruncatedFrame(WireError):
    """EOF mid-frame: the peer vanished between a header and its payload."""

    code = "bad_frame"


class VersionMismatch(WireError):
    """Hello carried an unsupported protocol version."""

    code = "version_mismatch"


class RemoteError(WireError):
    """An ``error`` frame received from the peer, re-raised locally."""

    def __init__(self, code: str, message: str = ""):
        super().__init__(f"{code}: {message}" if message else code)
        self.code = code
        self.message = message


def error_frame(code: str, message: str = "", **extra) -> dict:
    """Build a typed ``error`` frame (``code`` must be a known code)."""
    if code not in ERROR_CODES:
        raise ValueError(f"unknown error code {code!r}")
    frame = {"type": "error", "code": code, "message": message}
    frame.update(extra)
    return frame


# ----------------------------------------------------------------------
# codec
# ----------------------------------------------------------------------
def encode_frame(
    obj: dict, *, encoding: str = "json", max_frame: int = DEFAULT_MAX_FRAME
) -> bytes:
    """Serialize one frame (header + payload).  Raises
    :class:`FrameTooLarge` when the encoded payload would exceed
    ``max_frame`` and :class:`BadFrame` for an unknown encoding."""
    tag = ENCODINGS.get(encoding)
    if tag is None:
        raise BadFrame(f"unknown encoding {encoding!r}")
    if encoding == "msgpack":
        if not HAVE_MSGPACK:
            raise BadFrame("msgpack encoding requested but not installed")
        payload = _msgpack.packb(obj, use_bin_type=True)
    else:
        payload = json.dumps(obj, separators=(",", ":")).encode()
    if len(payload) > max_frame:
        raise FrameTooLarge(
            f"frame payload {len(payload)}B exceeds max {max_frame}B"
        )
    return _HEADER.pack(len(payload), tag) + payload


def decode_frame(
    buf: bytes, *, max_frame: int = DEFAULT_MAX_FRAME
) -> Tuple[dict, int]:
    """Decode one frame from the head of ``buf``.

    Returns ``(frame, consumed_bytes)``.  Raises :class:`TruncatedFrame`
    when ``buf`` holds less than one whole frame (callers with a live
    stream treat that as "read more"), :class:`FrameTooLarge` /
    :class:`BadFrame` on protocol violations.
    """
    if len(buf) < HEADER_SIZE:
        raise TruncatedFrame(f"{len(buf)}B is shorter than a frame header")
    length, tag = _HEADER.unpack_from(buf)
    if length > max_frame:
        raise FrameTooLarge(
            f"declared payload {length}B exceeds max {max_frame}B"
        )
    end = HEADER_SIZE + length
    if len(buf) < end:
        raise TruncatedFrame(f"payload truncated at {len(buf) - HEADER_SIZE}B")
    payload = buf[HEADER_SIZE:end]
    name = _ENCODING_NAMES.get(tag)
    if name is None:
        raise BadFrame(f"unknown encoding tag {tag}")
    try:
        if name == "msgpack":
            if not HAVE_MSGPACK:
                raise BadFrame("msgpack frame received but not installed")
            obj = _msgpack.unpackb(payload, raw=False)
        else:
            obj = json.loads(payload.decode())
    except BadFrame:
        raise
    except Exception as err:  # undecodable payload, whatever the cause
        raise BadFrame(f"undecodable {name} payload: {err}") from None
    if not isinstance(obj, dict) or not isinstance(obj.get("type"), str):
        raise BadFrame("frame payload is not a dict with a 'type'")
    return obj, end


class FrameStream:
    """Blocking framed view over a connected socket.

    One instance per connection per side.  ``send`` writes one whole
    frame; ``recv`` returns the next frame, ``None`` on a clean EOF at
    a frame boundary, and raises :class:`TruncatedFrame` on EOF
    mid-frame, :class:`FrameTooLarge`/:class:`BadFrame` on corrupt
    bytes (after which the stream is unusable — there is no resync).
    ``socket.timeout`` propagates so callers can bound every read.
    """

    def __init__(
        self,
        sock: socket.socket,
        *,
        encoding: str = "json",
        max_frame: int = DEFAULT_MAX_FRAME,
    ):
        if encoding not in ENCODINGS:
            raise BadFrame(f"unknown encoding {encoding!r}")
        self.sock = sock
        self.encoding = encoding
        self.max_frame = int(max_frame)
        self._buf = bytearray()
        self.bytes_in = 0
        self.bytes_out = 0

    def send(self, frame: dict) -> int:
        data = encode_frame(
            frame, encoding=self.encoding, max_frame=self.max_frame
        )
        self.sock.sendall(data)
        self.bytes_out += len(data)
        return len(data)

    def recv(self, timeout: float | None = None) -> dict | None:
        """Next frame (``None`` = clean EOF).  ``timeout`` overrides the
        socket timeout for this read only."""
        if timeout is not None:
            self.sock.settimeout(timeout)
        while True:
            try:
                frame, used = decode_frame(
                    bytes(self._buf), max_frame=self.max_frame
                )
            except TruncatedFrame:
                chunk = self.sock.recv(65536)
                if not chunk:
                    if self._buf:
                        raise TruncatedFrame(
                            f"EOF with {len(self._buf)}B of partial frame"
                        ) from None
                    return None
                self.bytes_in += len(chunk)
                self._buf.extend(chunk)
                continue
            del self._buf[:used]
            return frame

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


# ----------------------------------------------------------------------
# value serialization (bit-exact float64 round trips)
# ----------------------------------------------------------------------
_ENV_FIELDS = (
    "bandwidth_up",
    "bandwidth_down",
    "speedup",
    "p_compute",
    "p_idle",
    "p_transfer",
)


def env_to_wire(env: Environment) -> dict:
    return {f: float(getattr(env, f)) for f in _ENV_FIELDS}


def wire_to_env(d: dict) -> Environment:
    try:
        return Environment(**{f: float(d[f]) for f in _ENV_FIELDS})
    except (KeyError, TypeError, ValueError) as err:
        raise BadFrame(f"malformed env: {err}") from None


def reply_to_wire(reply) -> dict:
    """Serialize a :class:`~repro.service.broker.BrokerReply`.

    ``phases`` are deliberately dropped: they are solver provenance, not
    part of the serving contract, and every existing consumer
    (controllers, sessions, fallbacks) treats them as optional.
    """
    res = reply.result
    return {
        "result": None
        if res is None
        else {
            "min_cut": float(res.min_cut),
            "local_mask": [int(b) for b in np.asarray(res.local_mask, bool)],
        },
        "cache_hit": bool(reply.cache_hit),
        "coalesced": bool(reply.coalesced),
        "tick": int(reply.tick),
        "rejected": bool(reply.rejected),
        "degraded": bool(reply.degraded),
        "timed_out": bool(reply.timed_out),
    }


def wire_to_reply(d: dict):
    """Rehydrate a :class:`~repro.service.broker.BrokerReply`."""
    from repro.service.broker import BrokerReply  # circular at import time

    try:
        res = d["result"]
        result = (
            None
            if res is None
            else MCOPResult(
                min_cut=float(res["min_cut"]),
                local_mask=np.asarray(res["local_mask"], dtype=bool),
                phases=[],
            )
        )
        return BrokerReply(
            result,
            cache_hit=bool(d["cache_hit"]),
            coalesced=bool(d["coalesced"]),
            tick=int(d["tick"]),
            rejected=bool(d["rejected"]),
            degraded=bool(d["degraded"]),
            timed_out=bool(d["timed_out"]),
        )
    except (KeyError, TypeError, ValueError) as err:
        raise BadFrame(f"malformed reply: {err}") from None
