"""Weighted-fair queueing for the offload broker's flush order.

PR 4's two-level lanes (elastic ahead of user) were enough for one
dominant tenant, but under mixed multi-tenant load a chatty tenant can
monopolize every tick while a light one starves.  This module replaces
the lane *sort* with a real scheduler:

* **Strict priority lane** (``lane="elastic"``) — fleet resize events
  drain first, FIFO, and are exempt from backpressure: a shrinking
  fleet must re-place before any user refresh is served a placement
  solved for capacity that no longer exists.
* **Deficit round robin** over per-tenant FIFO queues for the user
  lane.  Every rotation round credits each backlogged tenant
  ``quantum × weight``; a tenant then serves one request per unit of
  accumulated deficit.  Rotation order is tenant-registration order and
  everything is integer/FIFO-deterministic — the same submissions always
  drain in the same order (asserted by the fairness tests).  Over any
  backlogged window tenants share tick capacity proportionally to their
  weights; fractional weights work because deficit accumulates across
  rounds.
* **Backpressure on queued bins** — the broker's unit of solver work is
  the *distinct* (tenant, environment-bin) pair, not the request (all
  same-bin requests coalesce into one solve).  The cap therefore counts
  distinct queued bins: a submission that would open a new bin past
  ``max_queued_bins`` is rejected (the broker resolves its future with a
  rejection reply), while a request joining an already-queued bin is
  always admitted — it costs no additional solver work.

* **Load-adaptive weights** (optional, per tenant) — a tenant may opt
  into inverse recent-latency weighting (:meth:`set_adaptive`): the
  broker reports each tick's per-tenant service latency, an EWMA tracks
  it, and the tenant's effective weight scales by mean-latency/own-EWMA
  (clamped), so a tenant whose ticks keep consuming the solver is
  automatically damped and light tenants are boosted.  Weights only
  move between drains, so a drain is still fully deterministic.

The scheduler is transport-agnostic and holds opaque items; the broker
wraps its requests in :class:`QueueEntry`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Hashable, Iterable

__all__ = ["QueueEntry", "WeightedFairScheduler"]

PRIORITY_LANE = "elastic"


@dataclasses.dataclass
class QueueEntry:
    """One queued unit of work.

    Attributes:
      tenant:  scheduling principal (per-tenant weight/queue).
      item:    opaque payload (the broker's request object).
      bin_key: hashable coalescing bin; backpressure counts distinct
               queued (tenant, bin_key) pairs in the user lane.
      lane:    ``"user"`` (weighted-fair) or ``"elastic"`` (strict
               priority, exempt from backpressure).
    """

    tenant: str
    item: Any
    bin_key: Hashable
    lane: str = "user"


class WeightedFairScheduler:
    """Deficit-round-robin queue with a strict priority lane.

    Parameters:
      quantum:         deficit credited per (weight-1.0) tenant per
                       rotation round.  1.0 means "one request per round
                       per unit weight" — the natural unit here, since
                       every request costs one coalescing slot.
      max_queued_bins: backpressure cap on distinct queued user-lane
                       (tenant, bin) pairs; ``None`` disables rejection.
    """

    def __init__(
        self,
        *,
        quantum: float = 1.0,
        max_queued_bins: int | None = None,
    ):
        if quantum <= 0:
            raise ValueError("quantum must be positive")
        if max_queued_bins is not None and max_queued_bins <= 0:
            raise ValueError("max_queued_bins must be positive (or None)")
        self.quantum = float(quantum)
        self.max_queued_bins = max_queued_bins
        self._tenants: list[str] = []            # rotation order
        self._weights: dict[str, float] = {}
        self._queues: dict[str, deque[QueueEntry]] = {}
        self._deficit: dict[str, float] = {}
        self._priority: deque[QueueEntry] = deque()
        self._bin_counts: dict[tuple[str, Hashable], int] = {}
        self._adaptive: dict[str, dict] = {}  # load-adaptive weight state
        self._cursor = 0  # rotation position, persisted ACROSS drains

    # -- tenants ---------------------------------------------------------
    def ensure_tenant(self, name: str, *, weight: float = 1.0) -> None:
        """Register ``name`` in the rotation (idempotent; keeps order)."""
        if name not in self._weights:
            self._tenants.append(name)
            self._queues[name] = deque()
            self._deficit[name] = 0.0
        self.set_weight(name, weight)

    def set_weight(self, name: str, weight: float) -> None:
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        if name not in self._weights and name not in self._queues:
            raise KeyError(f"unknown tenant {name!r}; call ensure_tenant first")
        self._weights[name] = float(weight)
        adaptive = self._adaptive.get(name)
        if adaptive is not None:
            adaptive["base"] = float(weight)

    def weight(self, name: str) -> float:
        return self._weights[name]

    # -- load-adaptive weights -------------------------------------------
    def set_adaptive(
        self,
        name: str,
        *,
        alpha: float = 0.25,
        floor: float = 0.25,
        ceiling: float = 4.0,
    ) -> None:
        """Opt ``name`` into load-adaptive weighting.

        The broker (or any driver) reports per-tenant service latency via
        :meth:`observe_latency`; each report updates an EWMA and
        recomputes every adaptive tenant's effective weight as::

            base × (mean latency across adaptive tenants) / (own EWMA)

        clamped to ``[base × floor, base × ceiling]`` — inverse
        recent-latency fairness: a tenant whose work keeps consuming the
        solver (high service latency) is damped, a light one boosted, so
        expensive ticks cost share.  Static-weight tenants are
        untouched, and DRR determinism is preserved (weights only change
        inside ``observe_latency``, never mid-drain).
        """
        if name not in self._weights:
            raise KeyError(f"unknown tenant {name!r}; call ensure_tenant first")
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        if floor <= 0 or ceiling < floor:
            raise ValueError("need 0 < floor <= ceiling")
        self._adaptive[name] = {
            "alpha": float(alpha),
            "floor": float(floor),
            "ceiling": float(ceiling),
            "base": self._weights[name],
            "ewma": None,
        }

    def observe_latency(self, name: str, seconds: float) -> None:
        """Feed one service-latency sample for ``name`` (no-op for
        tenants without :meth:`set_adaptive`); rebalances all adaptive
        tenants' weights against each other."""
        state = self._adaptive.get(name)
        if state is None:
            return
        seconds = max(float(seconds), 0.0)
        state["ewma"] = (
            seconds
            if state["ewma"] is None
            else state["alpha"] * seconds + (1.0 - state["alpha"]) * state["ewma"]
        )
        observed = {
            n: s for n, s in self._adaptive.items() if s["ewma"] is not None
        }
        mean = sum(s["ewma"] for s in observed.values()) / len(observed)
        for n, s in observed.items():
            if s["ewma"] <= 0.0 or mean <= 0.0:
                self._weights[n] = s["base"]
                continue
            raw = s["base"] * mean / s["ewma"]
            self._weights[n] = min(
                max(raw, s["base"] * s["floor"]), s["base"] * s["ceiling"]
            )

    def adaptive_state(self, name: str) -> dict | None:
        """Copy of a tenant's adaptive-weight state (telemetry/tests)."""
        state = self._adaptive.get(name)
        return dict(state) if state is not None else None

    # -- submission ------------------------------------------------------
    def submit(self, entry: QueueEntry) -> bool:
        """Enqueue; returns False when backpressure rejects the entry.

        Priority-lane entries always enter.  A user-lane entry is
        rejected only when it would open a NEW (tenant, bin) pair past
        ``max_queued_bins`` — joining an already-queued bin is free
        (it coalesces into that bin's solve).
        """
        if entry.lane == PRIORITY_LANE:
            self._priority.append(entry)
            return True
        self.ensure_tenant(entry.tenant, weight=self._weights.get(entry.tenant, 1.0))
        bin_id = (entry.tenant, entry.bin_key)
        if bin_id not in self._bin_counts:
            if (
                self.max_queued_bins is not None
                and len(self._bin_counts) >= self.max_queued_bins
            ):
                return False
            self._bin_counts[bin_id] = 0
        self._bin_counts[bin_id] += 1
        self._queues[entry.tenant].append(entry)
        return True

    def requeue(self, entries: Iterable[QueueEntry]) -> None:
        """Push entries back at the FRONT, preserving their order.

        The broker's failure containment: a failed tick returns its
        unresolved requests so the next tick retries them before any
        newer work.  Bypasses backpressure — these entries were already
        admitted once.
        """
        entries = list(entries)
        for entry in reversed(entries):
            if entry.lane == PRIORITY_LANE:
                self._priority.appendleft(entry)
            else:
                self.ensure_tenant(
                    entry.tenant, weight=self._weights.get(entry.tenant, 1.0)
                )
                bin_id = (entry.tenant, entry.bin_key)
                self._bin_counts[bin_id] = self._bin_counts.get(bin_id, 0) + 1
                self._queues[entry.tenant].appendleft(entry)

    def expire(self, predicate) -> list[QueueEntry]:
        """Remove and return every queued entry matching ``predicate``.

        The broker's deadline sweep: entries whose request outlived its
        deadline are pulled out of the queues (priority lane first, then
        per-tenant FIFO in rotation order) so their futures can resolve
        as timed-out instead of waiting for a drain that may never reach
        them.  User-lane bin counts are released like :meth:`_pop`, so
        backpressure sees the freed bins immediately.
        """
        removed: list[QueueEntry] = []

        def split(q: deque[QueueEntry]) -> deque[QueueEntry]:
            kept: deque[QueueEntry] = deque()
            for entry in q:
                (removed if predicate(entry) else kept).append(entry)
            return kept

        self._priority = split(self._priority)
        for tenant in self._tenants:
            self._queues[tenant] = split(self._queues[tenant])
        for entry in removed:
            if entry.lane == PRIORITY_LANE:
                continue
            bin_id = (entry.tenant, entry.bin_key)
            left = self._bin_counts.get(bin_id, 1) - 1
            if left <= 0:
                self._bin_counts.pop(bin_id, None)
            else:
                self._bin_counts[bin_id] = left
        return removed

    # -- draining --------------------------------------------------------
    def _pop(self, tenant: str) -> QueueEntry:
        entry = self._queues[tenant].popleft()
        bin_id = (entry.tenant, entry.bin_key)
        left = self._bin_counts.get(bin_id, 1) - 1
        if left <= 0:
            self._bin_counts.pop(bin_id, None)
        else:
            self._bin_counts[bin_id] = left
        return entry

    def drain(self, budget: int | None = None) -> list[QueueEntry]:
        """Dequeue up to ``budget`` entries (all, when ``None``).

        Priority lane first (FIFO), then DRR rotation over tenant
        queues: each visit credits the tenant ``quantum × weight`` and
        serves one entry per whole unit of deficit, FIFO within a
        tenant.  BOTH the deficit and the rotation cursor persist across
        drains — a budget that exhausts mid-rotation resumes at the next
        tenant on the following drain, so repeated budgeted ticks share
        capacity by weight instead of starving tenants late in
        registration order.  Deficit resets when a tenant's queue
        empties, so an idle tenant cannot bank unbounded credit.
        """
        out: list[QueueEntry] = []

        def room() -> bool:
            return budget is None or len(out) < budget

        while self._priority and room():
            out.append(self._priority.popleft())

        while room() and any(self._queues[t] for t in self._tenants):
            tenant = self._tenants[self._cursor % len(self._tenants)]
            # advance BEFORE serving: if the budget exhausts on this
            # tenant (it already got its credit), the next drain resumes
            # at the following one
            self._cursor = (self._cursor + 1) % len(self._tenants)
            q = self._queues[tenant]
            if not q:
                continue  # idle tenants earn no credit
            # with sub-unit weights a visit may only accrue credit; the
            # loop converges because deficit grows monotonically
            self._deficit[tenant] += self.quantum * self._weights[tenant]
            while q and self._deficit[tenant] >= 1.0 and room():
                out.append(self._pop(tenant))
                self._deficit[tenant] -= 1.0
            if not q:
                self._deficit[tenant] = 0.0  # standard DRR reset
        return out

    # -- observability ---------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._priority) + sum(len(q) for q in self._queues.values())

    @property
    def queued_bins(self) -> int:
        """Distinct user-lane (tenant, bin) pairs currently queued."""
        return len(self._bin_counts)

    def pending_for(self, tenant: str) -> int:
        q = self._queues.get(tenant)
        return len(q) if q is not None else 0

    def deficits(self) -> dict[str, float]:
        """Per-tenant DRR deficit balances (copy) — the fairness gauge a
        dashboard watches: a persistently high deficit means the tenant
        keeps earning credit it cannot spend inside the tick budget."""
        return dict(self._deficit)
