"""Service layer: the offload broker that turns the solver into a server.

``broker``    — :class:`OffloadBroker`: async multi-tenant coalescing
                front end over ``mcop_batch`` with persistent per-tenant
                placement caches, fused tick pricing and tick telemetry.
``scheduler`` — :class:`WeightedFairScheduler`: deficit-round-robin
                flush order over per-tenant weights, a strict elastic
                priority lane, and backpressure on queued bins.
``session``   — :class:`BrokerSession`: one user's adaptive loop
                (paper Fig. 1) with solves routed through the broker.
``workload``  — deterministic seeded multi-user environment walks for
                tests, benchmarks and demos.
"""

from repro.service.broker import (
    BrokerReply,
    BrokerTelemetry,
    OffloadBroker,
    PlacementFuture,
    TickReport,
)
from repro.service.scheduler import QueueEntry, WeightedFairScheduler
from repro.service.session import BrokerSession
from repro.service.workload import (
    DEFAULT_REGIMES,
    Regime,
    WorkloadReport,
    environment_trace,
    run_workload,
    user_traces,
)

__all__ = [
    "BrokerReply",
    "BrokerTelemetry",
    "OffloadBroker",
    "PlacementFuture",
    "TickReport",
    "QueueEntry",
    "WeightedFairScheduler",
    "BrokerSession",
    "DEFAULT_REGIMES",
    "Regime",
    "WorkloadReport",
    "environment_trace",
    "run_workload",
    "user_traces",
]
