"""Service layer: the offload broker that turns the solver into a server.

``broker``   — :class:`OffloadBroker`: async multi-tenant coalescing
               front end over ``mcop_batch`` with persistent per-tenant
               placement caches and tick telemetry.
``session``  — :class:`BrokerSession`: one user's adaptive loop
               (paper Fig. 1) with solves routed through the broker.
``workload`` — deterministic seeded multi-user environment walks for
               tests, benchmarks and demos.
"""

from repro.service.broker import (
    BrokerReply,
    BrokerTelemetry,
    OffloadBroker,
    PlacementFuture,
    TickReport,
)
from repro.service.session import BrokerSession
from repro.service.workload import (
    DEFAULT_REGIMES,
    Regime,
    WorkloadReport,
    environment_trace,
    run_workload,
    user_traces,
)

__all__ = [
    "BrokerReply",
    "BrokerTelemetry",
    "OffloadBroker",
    "PlacementFuture",
    "TickReport",
    "BrokerSession",
    "DEFAULT_REGIMES",
    "Regime",
    "WorkloadReport",
    "environment_trace",
    "run_workload",
    "user_traces",
]
