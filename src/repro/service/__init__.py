"""Service layer: the offload broker that turns the solver into a server.

``broker``    — :class:`OffloadBroker`: async multi-tenant coalescing
                front end over ``mcop_batch`` with persistent per-tenant
                placement caches, fused tick pricing and tick telemetry.
``scheduler`` — :class:`WeightedFairScheduler`: deficit-round-robin
                flush order over per-tenant weights, a strict elastic
                priority lane, and backpressure on queued bins.
``session``   — :class:`BrokerSession`: one user's adaptive loop
                (paper Fig. 1) with solves routed through the broker;
                :class:`BatchSessionGroup`: K sessions as one
                array-native SessionBatch ticked vectorized.
``faults``    — :class:`FaultInjector`: seeded deterministic chaos
                (pure function of seed/site/tick/index) for the fault
                sites the broker tick exposes.
``resilience``— :class:`ResiliencePolicy`: retry/backoff, per-request
                deadlines, pallas→jax→reference circuit breaker, and
                graceful degradation to §4.3-safe fallback placements.
``workload``  — deterministic seeded multi-user environment walks for
                tests, benchmarks and demos, plus the vectorized
                :class:`TrafficGenerator` (Poisson arrivals, geometric
                churn) feeding batched session groups.
``wire``      — length-prefixed JSON/msgpack frame protocol of the
                cross-process serving plane (versioned hello, typed
                error frames, bit-exact float64 round trips).
``server``    — :class:`SolverServer`: the solver process owning the
                device and the broker, with a write-ahead request
                journal, background snapshot loop, and journaled warm
                restart.
``client``    — :class:`BrokerClient`: sessions over unix/TCP sockets
                with graceful reconnect and idempotent resubmission.
"""

from repro.service.broker import (
    BrokerReply,
    BrokerTelemetry,
    OffloadBroker,
    PlacementFuture,
    TickReport,
)
from repro.service.client import BrokerClient, ClientFuture, RemoteBatchGroup
from repro.service.faults import (
    FAULT_KINDS,
    FAULT_SITES,
    FaultDecision,
    FaultInjector,
    InjectedFault,
    ScriptedFaultInjector,
)
from repro.service.resilience import (
    BACKEND_ESCALATION,
    CircuitBreaker,
    InjectedClock,
    ResiliencePolicy,
    RetryPolicy,
)
from repro.service.scheduler import QueueEntry, WeightedFairScheduler
from repro.service.server import Journal, SolverServer, tcp_address, unix_address
from repro.service.session import BatchSessionGroup, BrokerSession
from repro.service.wire import (
    PROTOCOL_VERSION,
    BadFrame,
    FrameStream,
    FrameTooLarge,
    RemoteError,
    TruncatedFrame,
    VersionMismatch,
    WireError,
    decode_frame,
    encode_frame,
)
from repro.service.workload import (
    DEFAULT_REGIMES,
    Regime,
    TrafficGenerator,
    TrafficTick,
    WorkloadReport,
    environment_trace,
    run_batch_workload,
    run_workload,
    user_traces,
)

__all__ = [
    "BrokerReply",
    "BrokerTelemetry",
    "OffloadBroker",
    "PlacementFuture",
    "TickReport",
    "FAULT_KINDS",
    "FAULT_SITES",
    "FaultDecision",
    "FaultInjector",
    "InjectedFault",
    "ScriptedFaultInjector",
    "BACKEND_ESCALATION",
    "CircuitBreaker",
    "InjectedClock",
    "ResiliencePolicy",
    "RetryPolicy",
    "QueueEntry",
    "WeightedFairScheduler",
    "BrokerSession",
    "BatchSessionGroup",
    "PROTOCOL_VERSION",
    "WireError",
    "BadFrame",
    "FrameTooLarge",
    "TruncatedFrame",
    "VersionMismatch",
    "RemoteError",
    "FrameStream",
    "encode_frame",
    "decode_frame",
    "SolverServer",
    "Journal",
    "unix_address",
    "tcp_address",
    "BrokerClient",
    "ClientFuture",
    "RemoteBatchGroup",
    "DEFAULT_REGIMES",
    "Regime",
    "TrafficGenerator",
    "TrafficTick",
    "WorkloadReport",
    "environment_trace",
    "run_batch_workload",
    "run_workload",
    "user_traces",
]
