"""Deterministic fault injection for the serving tier.

A production broker fails in boring, recurring ways — a transient XLA
error on a solve dispatch, a cache node timing out, a pricing pass
hitting corrupted weights, a latency spike — and the resilience layer
(`repro.service.resilience`, wired through
:class:`~repro.service.broker.OffloadBroker`) must be testable against
*exactly reproducible* schedules of those failures.  Python's salted
``hash()`` and any RNG shared with the workload would make schedules
drift across processes or interleave with unrelated draws, so the
injector here is a **pure function of (seed, tick, site, index)**: the
decision for a given coordinate is computed from a keyed blake2b digest
and nothing else.  Two injectors built with the same seed agree on
every decision, in any process, in any call order — the property the
``-m property`` suite asserts.

Sites (where the broker/session tick consults the injector):

* ``"solve"``       — around each ``mcop_batch``/``solve_envs`` dispatch.
* ``"pricing"``     — around the vectorized pricing evaluations.
* ``"cache_load"``  — per cache probe during request classification.
* ``"cache_store"`` — per representative store at commit time.

Kinds of fault a firing decision carries:

* ``"error"``   — a transient exception (:class:`InjectedFault`) raised
  at the site, exercising retry/backoff and the circuit breaker.
* ``"corrupt"`` — NaN poisoning of a *copy* of the site's inputs
  (:func:`poison_batch` / :func:`poison_envs`), exercising the
  finite-weight validation in ``WCGBatch``/``solve_envs`` — corruption
  must be *detected and retried*, never silently solved.
* ``"latency"`` — a deterministic delay (``delay_s``) charged to the
  broker clock (injected clocks advance, real clocks sleep); results
  are unchanged, only tick latency telemetry moves.

With ``rate=0`` (or ``enabled=False``) every decision is a non-firing
no-op and the broker's event stream is bit-identical to a broker
without an injector — asserted by the parity tests in
``tests/test_faults.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = [
    "FAULT_SITES",
    "FAULT_KINDS",
    "InjectedFault",
    "FaultDecision",
    "FaultInjector",
    "ScriptedFaultInjector",
    "poison_batch",
    "poison_envs",
]

FAULT_SITES = ("solve", "pricing", "cache_load", "cache_store")
FAULT_KINDS = ("error", "corrupt", "latency")


class InjectedFault(RuntimeError):
    """A deliberately injected transient failure (retryable)."""

    def __init__(self, site: str, tick: int, index: int, kind: str = "error"):
        super().__init__(
            f"injected {kind} fault at site={site!r} tick={tick} index={index}"
        )
        self.site = site
        self.tick = tick
        self.index = index
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    """One (site, tick, index) coordinate's verdict."""

    fires: bool
    kind: str | None
    site: str
    tick: int
    index: int
    delay_s: float = 0.0


class FaultInjector:
    """Seeded deterministic injector: ``decide`` is a pure function.

    Parameters:
      seed:      schedule identity; equal seeds ⇒ identical schedules
                 in every process (keyed hashing, no salted ``hash``).
      rate:      default per-coordinate fault probability in [0, 1].
      rates:     optional per-site overrides, e.g. ``{"solve": 0.1}``
                 (sites not listed fall back to ``rate``).
      kinds:     fault kinds drawn uniformly when a coordinate fires.
      latency_s: base delay of a ``"latency"`` fault; the actual delay
                 is ``latency_s × (0.5 + u)`` with ``u`` from the same
                 deterministic stream, so spikes vary but replay.
      enabled:   master switch — ``False`` makes every decision a
                 non-firing no-op (tests flip it to end a fault storm).
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        rate: float = 0.0,
        rates: dict[str, float] | None = None,
        kinds: tuple[str, ...] = FAULT_KINDS,
        latency_s: float = 0.002,
        enabled: bool = True,
    ):
        if not 0.0 <= rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        for site, r in (rates or {}).items():
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}")
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1]")
        bad = [k for k in kinds if k not in FAULT_KINDS]
        if bad or not kinds:
            raise ValueError(f"unknown fault kinds {bad!r}")
        self.seed = int(seed)
        self._rate = float(rate)
        self._rates = dict(rates or {})
        self.kinds = tuple(kinds)
        self.latency_s = float(latency_s)
        self.enabled = bool(enabled)

    # -- the deterministic stream ----------------------------------------
    def _u(self, site: str, tick: int, index: int, stream: str) -> float:
        """Uniform [0, 1) keyed on the full coordinate.

        Distinct ``stream`` labels (fire / kind / delay) and distinct
        sites draw from independent hash streams: changing any component
        of the key decorrelates the value — the independence property
        the ``-m property`` suite checks.
        """
        h = hashlib.blake2b(
            f"{self.seed}|{site}|{tick}|{index}|{stream}".encode(),
            digest_size=8,
        )
        return int.from_bytes(h.digest(), "big") / 2.0**64

    def rate_for(self, site: str) -> float:
        return self._rates.get(site, self._rate)

    def decide(self, site: str, tick: int, index: int = 0) -> FaultDecision:
        """The (site, tick, index) coordinate's deterministic verdict."""
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}")
        rate = self.rate_for(site)
        if not self.enabled or rate <= 0.0:
            return FaultDecision(False, None, site, tick, index)
        if self._u(site, tick, index, "fire") >= rate:
            return FaultDecision(False, None, site, tick, index)
        kind = self.kinds[
            int(self._u(site, tick, index, "kind") * len(self.kinds))
            % len(self.kinds)
        ]
        delay = (
            self.latency_s * (0.5 + self._u(site, tick, index, "delay"))
            if kind == "latency"
            else 0.0
        )
        return FaultDecision(True, kind, site, tick, index, delay_s=delay)


class ScriptedFaultInjector(FaultInjector):
    """Exact-coordinate schedule for targeted chaos tests.

    ``schedule`` maps ``(site, tick, index) -> kind``; every other
    coordinate is a non-firing no-op.  Shares the master ``enabled``
    switch with the base class.
    """

    def __init__(
        self,
        schedule: dict[tuple[str, int, int], str],
        *,
        latency_s: float = 0.002,
        enabled: bool = True,
    ):
        super().__init__(0, rate=0.0, latency_s=latency_s, enabled=enabled)
        for (site, _tick, _index), kind in schedule.items():
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}")
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r}")
        self.schedule = dict(schedule)

    def decide(self, site: str, tick: int, index: int = 0) -> FaultDecision:
        kind = self.schedule.get((site, tick, index))
        if not self.enabled or kind is None:
            return FaultDecision(False, None, site, tick, index)
        delay = self.latency_s if kind == "latency" else 0.0
        return FaultDecision(True, kind, site, tick, index, delay_s=delay)


# -- corruption helpers ---------------------------------------------------
def poison_batch(batch):
    """A COPY of ``batch`` with one NaN-poisoned weight (corruption fault).

    The original is untouched, so a retry after the corruption is
    detected (``WCGBatch.validate_finite`` →
    :class:`~repro.core.graph.NonFiniteWeightError`) solves clean inputs.
    """
    w_local = np.array(batch.w_local, dtype=np.float64, copy=True)
    w_local[0, 0] = np.nan
    return dataclasses.replace(batch, w_local=w_local)


def poison_envs(envs):
    """A COPY of ``envs`` with row 0's uplink bandwidth NaN-poisoned.

    Caught by the environment validation at the mouth of
    ``CostModel.build_batch`` / ``solve_envs`` — the batch never reaches
    the solver.
    """
    bw = np.array(envs.bandwidth_up, dtype=np.float64, copy=True)
    bw[0] = np.nan
    return envs._replace(bandwidth_up=bw)
