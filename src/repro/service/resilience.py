"""Retry, deadline and circuit-breaker policies for the offload broker.

Before this layer a single failing solve aborted the *entire* broker
tick and re-raised to the caller — acceptable for a library, not for a
serving tier.  The paper gives us the safety net that makes graceful
degradation sound: the §4.3 no-offload clamp means the all-local plan
is *always* a valid placement, so on persistent failure a request can
be served a fallback (a stale cached bin if one exists, else the
no-offload plan) marked ``degraded=True`` instead of an exception.

The policy objects here are plain deterministic state machines — no
wall-clock reads, no randomness — so chaos tests replay bit-identically
under injected clocks:

* :class:`RetryPolicy` — bounded retries with exponential backoff;
  backoff time is charged to the broker's (possibly injected) clock.
* :class:`CircuitBreaker` — per-backend consecutive-failure counter
  that opens a backend for ``cooldown_ticks`` and escalates dispatches
  down the chain **pallas → jax → reference**: the reference solver is
  pure numpy and shares no failure domain with the device runtimes.
* :class:`ResiliencePolicy` — the bundle the broker accepts
  (``OffloadBroker(resilience=...)``): retry policy, an optional
  per-request deadline (in ticks; overdue queued requests resolve as
  :attr:`~repro.service.broker.BrokerReply.timed_out`), the degradation
  mode for quarantined work (``"fallback"`` serves safe placements,
  ``"requeue"`` retries next tick), and the optional breaker.

``resilience=None`` (the default) preserves the legacy contract
exactly: failures re-queue unresolved requests and re-raise, batched
session ticks stay atomic.  Everything in this module is opt-in.
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "InjectedClock",
    "RetryPolicy",
    "CircuitBreaker",
    "ResiliencePolicy",
    "BACKEND_ESCALATION",
]

# Escalation chain, fastest/most-fragile first.  The numpy reference
# solver is the terminal fallback: no XLA, no device, no compile cache.
BACKEND_ESCALATION = ("pallas", "jax", "reference")


class InjectedClock:
    """Deterministic monotonic clock for tests and replayable benchmarks.

    Reads return the current value; retry backoff and latency faults
    ``advance`` it instead of sleeping, so a chaos run's latency
    telemetry is an exact function of the fault schedule.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError("cannot advance a clock backwards")
        self._now += float(seconds)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff.

    ``max_retries`` is the number of RE-tries: a dispatch gets
    ``max_retries + 1`` attempts total.  Backoff for attempt ``a``
    (0-based, charged between attempt ``a`` and ``a+1``) is
    ``min(base_backoff_s × multiplier^a, max_backoff_s)``.
    """

    max_retries: int = 2
    base_backoff_s: float = 0.001
    multiplier: float = 2.0
    max_backoff_s: float = 0.050

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff times must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")

    @property
    def attempts(self) -> int:
        return self.max_retries + 1

    def backoff(self, attempt: int) -> float:
        return min(
            self.base_backoff_s * self.multiplier ** max(attempt, 0),
            self.max_backoff_s,
        )


class CircuitBreaker:
    """Per-backend breaker escalating pallas → jax → reference.

    ``threshold`` consecutive failures open a backend for
    ``cooldown_ticks`` broker ticks; while open, :meth:`backend` walks
    the escalation chain from the preferred backend to the first closed
    one (the terminal ``"reference"`` is returned even when open — there
    is nothing further to escalate to).  A success closes the counter;
    cooldown expiry re-admits the backend (half-open: the next failure
    streak re-opens it).
    """

    def __init__(self, *, threshold: int = 3, cooldown_ticks: int = 8):
        if threshold <= 0:
            raise ValueError("threshold must be positive")
        if cooldown_ticks <= 0:
            raise ValueError("cooldown_ticks must be positive")
        self.threshold = int(threshold)
        self.cooldown_ticks = int(cooldown_ticks)
        self.trips = 0  # lifetime count of open transitions
        self._consecutive: dict[str, int] = {}
        self._open_until: dict[str, int] = {}

    def is_open(self, backend: str, tick: int) -> bool:
        return tick < self._open_until.get(backend, 0)

    def backend(self, preferred: str, tick: int) -> str:
        """Effective backend for this dispatch, given open circuits."""
        try:
            start = BACKEND_ESCALATION.index(preferred)
        except ValueError:
            return preferred  # unknown backend: breaker does not apply
        for candidate in BACKEND_ESCALATION[start:]:
            if not self.is_open(candidate, tick):
                return candidate
        return BACKEND_ESCALATION[-1]

    def record_failure(self, backend: str, tick: int) -> bool:
        """Count one failure; returns True when this trip OPENED the circuit."""
        count = self._consecutive.get(backend, 0) + 1
        if count >= self.threshold:
            self._consecutive[backend] = 0
            self._open_until[backend] = tick + self.cooldown_ticks
            self.trips += 1
            return True
        self._consecutive[backend] = count
        return False

    def record_success(self, backend: str) -> None:
        self._consecutive[backend] = 0

    def state(self) -> dict:
        """Telemetry snapshot (copies; safe to mutate)."""
        return {
            "trips": self.trips,
            "consecutive": dict(self._consecutive),
            "open_until": dict(self._open_until),
        }


@dataclasses.dataclass
class ResiliencePolicy:
    """What :class:`~repro.service.broker.OffloadBroker` does on failure.

    Attributes:
      retry:          per-dispatch retry/backoff schedule.
      deadline_ticks: default per-request deadline — a request still
                      queued ``deadline_ticks`` ticks after submission
                      resolves as ``timed_out`` (``None`` = no default;
                      ``submit(..., deadline=)`` can still set one per
                      request).
      degrade:        what happens to a (bin, bucket)'s requests when
                      its flush exhausts retries — ``"fallback"`` serves
                      each a safe placement (stale cached bin if
                      available, else the §4.3 no-offload plan) marked
                      ``degraded=True``; ``"requeue"`` pushes them back
                      for the next tick (deadlines bound the wait).
      breaker:        optional shared :class:`CircuitBreaker`.
    """

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    deadline_ticks: int | None = None
    degrade: str = "fallback"
    breaker: CircuitBreaker | None = None

    def __post_init__(self) -> None:
        if self.degrade not in ("fallback", "requeue"):
            raise ValueError("degrade must be 'fallback' or 'requeue'")
        if self.deadline_ticks is not None and self.deadline_ticks <= 0:
            raise ValueError("deadline_ticks must be positive (or None)")
