"""Solver process: the wire-protocol front end that owns the device.

The management-plane / solver-worker split (ROADMAP "cross-process
broker"): one :class:`SolverServer` process owns the JAX device and the
:class:`~repro.service.broker.OffloadBroker`; N client processes host
sessions (:mod:`repro.service.client`) and talk
:mod:`repro.service.wire` frames over a unix or TCP socket.

Durability plane — what makes a crashed solver warm-startable:

* **Request journal** — every *accepted* submit is appended to a JSONL
  journal (write-ahead: the ``submit_ok`` ack is only sent after the
  entry is flushed), and every completed tick appends a tick marker.
  The journal is the replayable truth of what the broker was asked.
* **Background snapshot loop** — every ``snapshot_every_ticks`` ticks
  the server saves each tenant's
  :class:`~repro.core.placement_cache.PlacementCache` (atomic
  ``os.replace`` writes) stamped with the journal sequence number and
  broker tick it covers, then compacts the journal down to the
  uncovered tail.  No caller ever calls ``save_snapshot`` explicitly.
* **Warm restart** — :meth:`SolverServer.recover` loads the snapshots
  (fingerprint-guarded; a foreign or corrupt snapshot cold-starts),
  fast-forwards the broker's tick counter to the snapshot tick, then
  replays the journal tail: re-submitting each journaled request and
  re-running each journaled tick.  On the reference backend the
  replayed replies are BIT-identical to the uninterrupted run — same
  placements, same prices, same tick numbers, same degraded flags
  (asserted by ``tests/test_ipc_recovery.py``).
* **Idempotent resubmission** — replies are remembered per request id;
  a resubmitted id that was already replayed (or is still queued) is
  acknowledged without re-journaling, re-queueing, or touching the
  cache, so a reconnecting client can blindly resubmit its unresolved
  window and cache stats are never double-counted.

The serve loop is a single-threaded ``selectors`` reactor: frames are
processed in arrival order, ticks are client-driven (a ``tick`` frame
runs exactly one broker tick), and the broker is never entered
concurrently — the determinism that makes cross-process replies
``==``-identical to an in-process broker fed the same submission order.

Observability: per-frame spans (``wire.frame`` with ``transport`` and
frame-type labels) nest the broker's own tick spans, and wire traffic
feeds ``wire_frames`` / ``wire_bytes`` counters plus a
``wire_frame_handle_s`` histogram when a
:class:`~repro.obs.metrics.MetricsRegistry` is attached.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import selectors
import socket
import time
from typing import Callable

import numpy as np

from repro.core.cost_models import EnvArrays
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer
from repro.service.broker import OffloadBroker
from repro.service.wire import (
    DEFAULT_MAX_FRAME,
    PROTOCOL_VERSION,
    BadFrame,
    FrameTooLarge,
    TruncatedFrame,
    WireError,
    decode_frame,
    encode_frame,
    error_frame,
    reply_to_wire,
    supported_encodings,
    wire_to_env,
)

__all__ = ["Journal", "SolverServer", "unix_address", "tcp_address"]

JOURNAL_VERSION = 1


def unix_address(path) -> tuple:
    """Address tuple for a unix-domain socket at ``path``."""
    return ("unix", str(path))


def tcp_address(host: str = "127.0.0.1", port: int = 0) -> tuple:
    """Address tuple for a TCP socket (``port=0`` = ephemeral)."""
    return ("tcp", host, int(port))


class Journal:
    """Append-only JSONL write-ahead log of accepted work.

    Entries carry a monotonic ``seq``; ``replay`` tolerates a truncated
    final line (a SIGKILL mid-append) by skipping undecodable tail
    lines.  ``compact`` atomically rewrites the file keeping only
    entries newer than a sequence number — the snapshot loop's
    retention policy.
    """

    def __init__(self, path, *, fsync: bool = False):
        self.path = pathlib.Path(path)
        self.fsync = bool(fsync)
        self.seq = 0
        self._fh = None

    def open(self) -> None:
        if self._fh is None:
            fresh = not self.path.exists() or self.path.stat().st_size == 0
            self._fh = open(self.path, "a")
            if fresh:
                self.append({"op": "journal", "version": JOURNAL_VERSION})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def append(self, entry: dict) -> int:
        """Write one entry (auto-assigned ``seq``), flushed before the
        caller proceeds — the write-ahead guarantee the submit ack
        relies on.  Returns the assigned sequence number."""
        self.open()
        self.seq += 1
        entry = {"seq": self.seq, **entry}
        self._fh.write(json.dumps(entry, separators=(",", ":")) + "\n")
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        return self.seq

    @staticmethod
    def read(path) -> list[dict]:
        """All decodable entries of a journal file (missing file = []).

        A truncated or corrupt line — the tail a SIGKILL can leave —
        is skipped; entries after it still load (each line stands
        alone), preserving every whole record the kernel accepted.
        """
        path = pathlib.Path(path)
        if not path.exists():
            return []
        entries: list[dict] = []
        try:
            raw = path.read_text()
        except OSError:
            return []
        for line in raw.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(e, dict) and isinstance(e.get("seq"), int):
                entries.append(e)
        return entries

    def load(self) -> list[dict]:
        """Read the existing entries and adopt the highest seq so new
        appends continue the sequence."""
        entries = self.read(self.path)
        self.seq = max((e["seq"] for e in entries), default=0)
        return entries

    def compact(self, keep_after_seq: int) -> int:
        """Atomically drop entries with ``seq <= keep_after_seq``
        (they are covered by a snapshot).  Returns entries kept."""
        entries = [
            e
            for e in self.read(self.path)
            if e["seq"] > keep_after_seq and e.get("op") != "journal"
        ]
        tmp = self.path.with_name(self.path.name + ".compact.tmp")
        with open(tmp, "w") as f:
            f.write(
                json.dumps(
                    {"seq": 0, "op": "journal", "version": JOURNAL_VERSION},
                    separators=(",", ":"),
                )
                + "\n"
            )
            for e in entries:
                f.write(json.dumps(e, separators=(",", ":")) + "\n")
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        was_open = self._fh is not None
        self.close()
        os.replace(tmp, self.path)
        if was_open:
            self._fh = open(self.path, "a")
        return len(entries)


@dataclasses.dataclass
class _Conn:
    sock: socket.socket
    addr: object
    stream_encoding: str = "json"
    buf: bytearray = dataclasses.field(default_factory=bytearray)
    outbox: bytearray = dataclasses.field(default_factory=bytearray)
    ready: bool = False            # hello completed
    name: str = "?"
    closing: bool = False          # close once the outbox drains


class SolverServer:
    """One solver process: wire frames in, broker replies out.

    Parameters:
      broker:   the :class:`~repro.service.broker.OffloadBroker` this
                process owns.  Tenants must be registered *before*
                :meth:`recover` — the journal names tenants, it cannot
                reconstruct their profiles/cost models.
      address:  ``("unix", path)`` or ``("tcp", host, port)`` — see
                :func:`unix_address` / :func:`tcp_address`.
      journal_path: JSONL write-ahead log (``None`` disables the
                durability plane: no journal, no snapshots, no warm
                restart — an ephemeral solver).
      snapshot_dir: directory for per-tenant cache snapshots.
      snapshot_every_ticks: background snapshot cadence; every Nth tick
                the serve loop saves all tenant caches and compacts the
                journal.  Explicit ``snapshot`` frames force a pass.
      compact_journal: rewrite the journal to the uncovered tail at
                each snapshot (default True).
      max_frame: refuse frames larger than this many payload bytes.
      tracer / metrics: optional observability plane (pure observers).
      clock:    serve-loop clock for frame-handling timing only; never
                read unless metrics are attached.
    """

    def __init__(
        self,
        broker: OffloadBroker,
        *,
        address: tuple,
        journal_path=None,
        snapshot_dir=None,
        snapshot_every_ticks: int = 8,
        compact_journal: bool = True,
        fsync: bool = False,
        max_frame: int = DEFAULT_MAX_FRAME,
        tracer: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if address[0] not in ("unix", "tcp"):
            raise ValueError(f"unknown address family {address[0]!r}")
        if snapshot_every_ticks <= 0:
            raise ValueError("snapshot_every_ticks must be positive")
        self.broker = broker
        self.address = address
        self.transport = address[0]
        self.journal = (
            Journal(journal_path, fsync=fsync)
            if journal_path is not None
            else None
        )
        self.snapshot_dir = (
            pathlib.Path(snapshot_dir) if snapshot_dir is not None else None
        )
        self.snapshot_every_ticks = int(snapshot_every_ticks)
        self.compact_journal = bool(compact_journal)
        self.max_frame = int(max_frame)
        self.tracer = tracer
        self.metrics = metrics
        self.clock = clock
        self._sel: selectors.BaseSelector | None = None
        self._listener: socket.socket | None = None
        self._running = False
        self._ticks_served = 0
        self._snapshot_seq = 0         # journal seq the last snapshot covers
        # request id → unresolved future / wire-encoded reply / owner conn
        self._inflight: dict[str, object] = {}
        self._replies: dict[str, dict] = {}
        self._owners: dict[str, _Conn] = {}
        # server-side batch session groups: gid → (group, tenant)
        self._groups: dict[str, object] = {}
        self._group_owner: dict[str, _Conn] = {}
        self._group_seq = 0

    # -- observability helpers ------------------------------------------
    def _span(self, name: str, **attrs):
        return (
            self.tracer.span(name, **attrs)
            if self.tracer is not None
            else NULL_SPAN
        )

    def _count_frame(self, direction: str, ftype: str, nbytes: int) -> None:
        if self.metrics is None:
            return
        self.metrics.counter(
            "wire_frames",
            direction=direction,
            type=ftype,
            transport=self.transport,
        ).inc()
        self.metrics.counter(
            "wire_bytes", direction=direction, transport=self.transport
        ).inc(nbytes)

    # -- durability plane ------------------------------------------------
    def _tenant_snapshot_path(self, name: str) -> pathlib.Path:
        return self.snapshot_dir / f"{name}.snapshot.json"

    def snapshot_now(self) -> int:
        """One background-loop pass: save every tenant cache (stamped
        with the covered journal seq + broker tick), then compact the
        journal to the uncovered tail.  Returns the covered seq."""
        if self.snapshot_dir is None or self.journal is None:
            return 0
        self.snapshot_dir.mkdir(parents=True, exist_ok=True)
        seq = self.journal.seq
        meta = {"journal_seq": seq, "tick": self.broker._tick}
        for name, t in self.broker._tenants.items():
            t.cache.save(
                self._tenant_snapshot_path(name),
                fingerprint=t.fingerprint,
                meta=meta,
            )
        self._snapshot_seq = seq
        if self.compact_journal:
            self.journal.compact(seq)
        return seq

    def recover(self) -> dict:
        """Warm-start from the persisted snapshots + journal tail.

        Loads each tenant's snapshot (fingerprint-guarded; rejects
        degrade to a cold cache and force a full-journal replay), sets
        the broker's tick counter to the snapshot's tick so replayed
        tick numbers line up with the uninterrupted history, then
        replays the journal tail: submits re-enter the queue in
        journal order and tick markers re-run ``broker.tick()``.
        Replayed replies land in the idempotent reply log, so clients
        resubmitting their unresolved window are answered without any
        re-solving or double-counted cache stats.

        Returns a summary dict (``replayed_submits``,
        ``replayed_ticks``, ``resume_tick``, ``resume_seq``).
        """
        if self.journal is None:
            return {
                "replayed_submits": 0,
                "replayed_ticks": 0,
                "resume_tick": self.broker._tick,
                "resume_seq": 0,
            }
        entries = self.journal.load()
        base_seq = 0
        base_tick = 0
        if self.snapshot_dir is not None and self.broker._tenants:
            metas = []
            for name, t in self.broker._tenants.items():
                _, meta = t.cache.load_with_meta(
                    self._tenant_snapshot_path(name), fingerprint=t.fingerprint
                )
                metas.append(meta)
            # every snapshot pass stamps all tenants with one (seq, tick);
            # a missing/rejected snapshot (meta None) forces replay from 0
            if metas and all(m is not None for m in metas):
                base_seq = min(int(m.get("journal_seq", 0)) for m in metas)
                base_tick = min(int(m.get("tick", 0)) for m in metas)
        self._snapshot_seq = base_seq
        self.broker.restore_tick(base_tick)
        submits = ticks = 0
        for e in entries:
            if e["seq"] <= base_seq:
                continue
            op = e.get("op")
            if op == "submit":
                rid = e.get("id")
                if rid in self._inflight or rid in self._replies:
                    continue
                try:
                    fut = self.broker.submit(
                        e["tenant"],
                        wire_to_env(e["env"]),
                        lane=e.get("lane", "user"),
                        deadline=e.get("deadline"),
                    )
                except Exception:
                    continue  # tenant no longer registered: drop the entry
                submits += 1
                if fut.done:
                    self._replies[rid] = reply_to_wire(fut.result)
                else:
                    self._inflight[rid] = fut
            elif op == "tick":
                self.broker.tick()
                ticks += 1
                self._harvest_resolved()
        return {
            "replayed_submits": submits,
            "replayed_ticks": ticks,
            "resume_tick": self.broker._tick,
            "resume_seq": self.journal.seq,
        }

    def _harvest_resolved(self) -> list[str]:
        """Move freshly resolved futures into the reply log; returns the
        resolved request ids (in insertion order)."""
        done = [
            rid for rid, fut in self._inflight.items() if fut.done
        ]
        for rid in done:
            fut = self._inflight.pop(rid)
            self._replies[rid] = reply_to_wire(fut.result)
        return done

    # -- socket plumbing -------------------------------------------------
    def bind(self) -> tuple:
        """Create + bind + listen; returns the effective address (the
        resolved port for ``("tcp", host, 0)``)."""
        if self.transport == "unix":
            path = self.address[1]
            try:
                os.unlink(path)
            except OSError:
                pass
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.bind(path)
        else:
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((self.address[1], self.address[2]))
            self.address = ("tcp", *sock.getsockname())
        sock.listen(64)
        sock.setblocking(False)
        self._listener = sock
        self._sel = selectors.DefaultSelector()
        self._sel.register(sock, selectors.EVENT_READ, None)
        if self.journal is not None:
            self.journal.open()
        return self.address

    def close(self) -> None:
        if self._sel is not None:
            for key in list(self._sel.get_map().values()):
                if key.data is not None:
                    self._close_conn(key.data)
            self._sel.close()
            self._sel = None
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        if self.transport == "unix":
            try:
                os.unlink(self.address[1])
            except OSError:
                pass
        if self.journal is not None:
            self.journal.close()

    def stop(self) -> None:
        self._running = False

    def serve_forever(
        self, *, max_ticks: int | None = None, poll_s: float = 0.1
    ) -> None:
        """Reactor loop: accept, read frames, answer.  Returns after
        ``max_ticks`` broker ticks have been served (``None`` = until
        :meth:`stop`)."""
        if self._sel is None:
            self.bind()
        self._running = True
        try:
            while self._running:
                for key, mask in self._sel.select(poll_s):
                    if key.data is None:
                        self._accept()
                    else:
                        conn = key.data
                        if mask & selectors.EVENT_WRITE:
                            self._flush_outbox(conn)
                        if mask & selectors.EVENT_READ:
                            self._on_readable(conn)
                if max_ticks is not None and self._ticks_served >= max_ticks:
                    break
        finally:
            self.close()

    def _accept(self) -> None:
        try:
            sock, addr = self._listener.accept()
        except OSError:
            return
        sock.setblocking(False)
        conn = _Conn(sock, addr)
        self._sel.register(sock, selectors.EVENT_READ, conn)
        if self.metrics is not None:
            self.metrics.gauge(
                "wire_connections", transport=self.transport
            ).add(1)

    def _close_conn(self, conn: _Conn) -> None:
        try:
            self._sel.unregister(conn.sock)
        except (KeyError, ValueError):
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        for rid, owner in list(self._owners.items()):
            if owner is conn:
                del self._owners[rid]
        for gid, owner in list(self._group_owner.items()):
            if owner is conn:
                del self._group_owner[gid]
        if self.metrics is not None:
            self.metrics.gauge(
                "wire_connections", transport=self.transport
            ).add(-1)

    def _interest(self, conn: _Conn) -> None:
        events = selectors.EVENT_READ
        if conn.outbox:
            events |= selectors.EVENT_WRITE
        try:
            self._sel.modify(conn.sock, events, conn)
        except (KeyError, ValueError):
            pass

    def _send(self, conn: _Conn, frame: dict) -> None:
        try:
            data = encode_frame(
                frame, encoding=conn.stream_encoding, max_frame=self.max_frame
            )
        except WireError:
            data = encode_frame(
                error_frame("server_error", "reply could not be encoded"),
                encoding=conn.stream_encoding,
                max_frame=self.max_frame,
            )
        conn.outbox.extend(data)
        self._count_frame("out", frame.get("type", "?"), len(data))
        self._flush_outbox(conn)

    def _flush_outbox(self, conn: _Conn) -> None:
        while conn.outbox:
            try:
                sent = conn.sock.send(bytes(conn.outbox))
            except BlockingIOError:
                break
            except OSError:
                self._close_conn(conn)
                return
            if sent == 0:
                break
            del conn.outbox[:sent]
        if conn.closing and not conn.outbox:
            self._close_conn(conn)
            return
        self._interest(conn)

    def _fail(self, conn: _Conn, code: str, message: str, *, close: bool,
              **extra) -> None:
        """Best-effort typed error frame; optionally schedule the close
        once it drains (framing errors poison the stream)."""
        self._send(conn, error_frame(code, message, **extra))
        if close:
            conn.closing = True
            self._flush_outbox(conn)

    def _on_readable(self, conn: _Conn) -> None:
        try:
            chunk = conn.sock.recv(65536)
        except BlockingIOError:
            return
        except OSError:
            self._close_conn(conn)
            return
        if not chunk:
            self._close_conn(conn)
            return
        conn.buf.extend(chunk)
        while True:
            try:
                frame, used = decode_frame(
                    bytes(conn.buf), max_frame=self.max_frame
                )
            except TruncatedFrame:
                return  # wait for more bytes
            except (FrameTooLarge, BadFrame) as err:
                # the length prefix cannot be trusted: no resync possible
                self._fail(conn, err.code, str(err), close=True)
                return
            del conn.buf[:used]
            self._handle_frame(conn, frame, used)
            if conn.closing:
                return

    # -- frame dispatch --------------------------------------------------
    def _handle_frame(self, conn: _Conn, frame: dict, nbytes: int) -> None:
        ftype = frame["type"]
        self._count_frame("in", ftype, nbytes)
        timer = (
            self.metrics.timer(
                "wire_frame_handle_s", transport=self.transport
            )
            if self.metrics is not None
            else NULL_SPAN
        )
        with timer, self._span(
            "wire.frame", type=ftype, transport=self.transport,
            client=conn.name,
        ):
            if not conn.ready:
                if ftype == "hello":
                    self._on_hello(conn, frame)
                else:
                    self._fail(
                        conn, "not_ready",
                        f"first frame must be hello, got {ftype!r}",
                        close=True,
                    )
                return
            handler = self._HANDLERS.get(ftype)
            if handler is None:
                self._fail(
                    conn, "unknown_type", f"unknown frame type {ftype!r}",
                    close=False,
                )
                return
            try:
                handler(self, conn, frame)
            except WireError as err:
                self._fail(conn, err.code, str(err), close=False)
            except Exception as err:  # noqa: BLE001 — serve loop must survive
                self._fail(
                    conn, "server_error",
                    f"{type(err).__name__}: {err}", close=False,
                )

    def _on_hello(self, conn: _Conn, frame: dict) -> None:
        version = frame.get("version")
        if version != PROTOCOL_VERSION:
            self._fail(
                conn, "version_mismatch",
                f"server speaks v{PROTOCOL_VERSION}, client sent {version!r}",
                close=True, server_version=PROTOCOL_VERSION,
            )
            return
        proposed = frame.get("encoding", "json")
        encoding = proposed if proposed in supported_encodings() else "json"
        conn.stream_encoding = encoding
        conn.name = str(frame.get("client", conn.name))
        conn.ready = True
        self._send(
            conn,
            {
                "type": "hello_ok",
                "version": PROTOCOL_VERSION,
                "encoding": encoding,
                "encodings": list(supported_encodings()),
                "backend": self.broker.backend,
                "tenants": sorted(self.broker._tenants),
                "max_frame": self.max_frame,
                "tick": self.broker._tick,
            },
        )

    def _on_submit(self, conn: _Conn, frame: dict) -> None:
        rid = frame.get("id")
        if not isinstance(rid, str) or not rid:
            raise BadFrame("submit needs a non-empty string 'id'")
        tenant = frame.get("tenant")
        if tenant not in self.broker._tenants:
            self._fail(
                conn, "unknown_tenant", f"no tenant {tenant!r}",
                close=False, id=rid,
            )
            return
        # idempotent resubmission: an id we already answered is served
        # from the reply log; an id still queued just re-binds its owner.
        # Neither touches the journal, the queue, or the cache counters.
        # reply rides BEFORE the ack so the client's future is already
        # resolved when the synchronous submit() returns — mirroring the
        # in-process broker, where an immediately-resolved future (e.g.
        # backpressure rejection) is .done at submit time.
        stored = self._replies.get(rid)
        if stored is not None:
            self._send(conn, {"type": "reply", "id": rid, **stored})
            self._send(conn, {"type": "submit_ok", "id": rid,
                              "replayed": True})
            return
        if rid in self._inflight:
            self._owners[rid] = conn
            self._send(conn, {"type": "submit_ok", "id": rid,
                              "replayed": True})
            return
        if self.broker._tenants[tenant].profile is None:
            self._fail(
                conn, "bad_request",
                f"tenant {tenant!r} has no profile; raw-graph submission "
                "is not supported over the wire", close=False, id=rid,
            )
            return
        env = wire_to_env(frame.get("env") or {})
        lane = frame.get("lane", "user")
        deadline = frame.get("deadline")
        if self.journal is not None:
            self.journal.append(
                {
                    "op": "submit",
                    "id": rid,
                    "tenant": tenant,
                    "env": frame["env"],
                    "lane": lane,
                    "deadline": deadline,
                }
            )
        fut = self.broker.submit(tenant, env, lane=lane, deadline=deadline)
        if fut.done:  # immediate backpressure rejection
            self._replies[rid] = reply_to_wire(fut.result)
            self._send(conn, {"type": "reply", "id": rid,
                              **self._replies[rid]})
        else:
            self._inflight[rid] = fut
            self._owners[rid] = conn
        self._send(conn, {"type": "submit_ok", "id": rid, "replayed": False})

    def _on_tick(self, conn: _Conn, frame: dict) -> None:
        budget = frame.get("budget")
        report = self.broker.tick(budget=budget)
        self._ticks_served += 1
        if self.journal is not None:
            self.journal.append({"op": "tick", "tick": report.tick})
        for rid in self._harvest_resolved():
            owner = self._owners.pop(rid, None)
            if owner is not None:
                self._send(
                    owner,
                    {"type": "reply", "id": rid, **self._replies[rid]},
                )
        self._flush_group_reports()
        self._send(
            conn,
            {
                "type": "tick_report",
                "tick": report.tick,
                "requests": report.requests,
                "cache_hits": report.cache_hits,
                "coalesced": report.coalesced,
                "solved": report.solved,
                "dispatches": report.dispatches,
                "queue_depth": report.queue_depth,
                "degraded": report.degraded,
                "timed_out": report.timed_out,
                "rejected": report.rejected,
                "batch_groups": report.batch_groups,
                "batch_sessions": report.batch_sessions,
                "latency_s": report.latency_s,
            },
        )
        if (
            self.journal is not None
            and self.snapshot_dir is not None
            and self._ticks_served % self.snapshot_every_ticks == 0
        ):
            with self._span("wire.snapshot", transport=self.transport):
                self.snapshot_now()

    def _on_register_batch(self, conn: _Conn, frame: dict) -> None:
        tenant = frame.get("tenant")
        if tenant not in self.broker._tenants:
            self._fail(conn, "unknown_tenant", f"no tenant {tenant!r}",
                       close=False)
            return
        capacity = frame.get("capacity")
        if not isinstance(capacity, int) or capacity <= 0:
            raise BadFrame("register_batch needs a positive int 'capacity'")
        group = self.broker.register_batch(
            tenant,
            capacity,
            threshold=float(frame.get("threshold", 0.10)),
            min_interval=int(frame.get("min_interval", 1)),
        )
        self._group_seq += 1
        gid = f"{tenant}#{self._group_seq}"
        self._groups[gid] = group
        self._group_owner[gid] = conn
        self._send(
            conn,
            {"type": "register_ok", "group": gid, "capacity": capacity},
        )

    def _on_observe_batch(self, conn: _Conn, frame: dict) -> None:
        gid = frame.get("group")
        group = self._groups.get(gid)
        if group is None:
            self._fail(conn, "unknown_group", f"no batch group {gid!r}",
                       close=False)
            return
        envs = frame.get("envs")
        try:
            arrays = EnvArrays(
                *[
                    np.asarray(envs[f], dtype=np.float64)
                    for f in EnvArrays._fields
                ]
            )
        except (KeyError, TypeError, ValueError) as err:
            raise BadFrame(f"malformed envs: {err}") from None
        group.observe(
            arrays,
            arrived=frame.get("arrived"),
            departed=frame.get("departed"),
        )
        self._group_owner[gid] = conn
        self._send(conn, {"type": "observe_ok", "group": gid})

    def _flush_group_reports(self) -> None:
        """Push each just-ticked group's summary to its owner."""
        for gid, group in self._groups.items():
            for report in group.drain():
                owner = self._group_owner.get(gid)
                if owner is None:
                    continue
                degraded = (
                    0
                    if report.degraded is None
                    else int(report.degraded.sum())
                )
                self._send(
                    owner,
                    {
                        "type": "batch_report",
                        "group": gid,
                        "active": int(report.active.sum()),
                        "due": report.due,
                        "hits": report.hits,
                        "solved": report.solved,
                        "coalesced": report.coalesced,
                        "degraded": degraded,
                        "min_cut": [float(v) for v in report.min_cut],
                        "gain": [float(v) for v in report.gain],
                    },
                )

    def _on_telemetry(self, conn: _Conn, frame: dict) -> None:
        caches = {
            name: dataclasses.asdict(t.cache.stats)
            for name, t in self.broker._tenants.items()
        }
        out = {
            "type": "telemetry_report",
            "summary": self.broker.telemetry.summary(),
            "caches": caches,
            "tick": self.broker._tick,
            "inflight": len(self._inflight),
            "journal_seq": self.journal.seq if self.journal else 0,
        }
        if frame.get("metrics") and self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        self._send(conn, out)

    def _on_snapshot(self, conn: _Conn, frame: dict) -> None:
        seq = self.snapshot_now()
        self._send(conn, {"type": "snapshot_ok", "seq": seq})

    def _on_ping(self, conn: _Conn, frame: dict) -> None:
        self._send(conn, {"type": "pong", "nonce": frame.get("nonce")})

    def _on_bye(self, conn: _Conn, frame: dict) -> None:
        conn.closing = True
        self._flush_outbox(conn)

    _HANDLERS = {
        "submit": _on_submit,
        "tick": _on_tick,
        "register_batch": _on_register_batch,
        "observe_batch": _on_observe_batch,
        "telemetry": _on_telemetry,
        "snapshot": _on_snapshot,
        "ping": _on_ping,
        "bye": _on_bye,
    }
